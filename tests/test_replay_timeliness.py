"""The timeliness-aware replay engine: virtual-clock stall arithmetic on
hand-built traces, disk-slot queueing, bounded-cache thrash accounting, the
cache-capacity sweep, parallel recording determinism, and the CSV artifact
shape (ISSUE 2 tentpole); plus the write path end-to-end (ISSUE 3): store
write-allocate/dirty-bit accounting, virtual-clock write-back occupancy,
the mutating bank workload, listener isolation and counter-reset fixes."""

import csv

import pytest

from repro.apps.bank import build_bank_app, populate_bank_store
from repro.pos.client import POSClient
from repro.pos.latency import REPLAY, LatencyModel, VirtualDisk
from repro.pos.store import ObjectStore
from repro.pos.trace import TraceEvent, trace_oids
from repro.predict.base import Predictor
from repro.predict.evaluate import (
    CSV_COLUMNS,
    RecordedTrace,
    _catalog,
    evaluate_workload,
    record_catalog,
    record_workload,
    replay,
    replay_baseline,
    write_csv,
)

# disk_load=10, think=1: every stall below is exact integer arithmetic
LAT = LatencyModel(disk_load=10.0, remote_hop=0.0, write_back=0.0, think=1.0,
                   parallel_per_ds=2)


class Scripted(Predictor):
    """Emit a fixed oid list at method entry and/or per-access."""

    name = "scripted"

    def __init__(self, on_entry=(), on_access_map=None):
        super().__init__()
        self._on_entry = list(on_entry)
        self._on_access = dict(on_access_map or {})

    def on_method_entry(self, method_key, this_oid):
        return self._emit(list(self._on_entry))

    def on_access(self, oid, cls):
        return self._emit(list(self._on_access.get(oid, ())))


def _store_with(n_objects: int, n_services: int = 1) -> tuple[ObjectStore, list[int]]:
    store = ObjectStore(n_services=n_services)
    oids = [store.put("Obj", {}) for _ in range(n_objects)]
    return store, oids


# ---------------------------------------------------------------------------
# VirtualDisk slot arithmetic
# ---------------------------------------------------------------------------


def test_virtual_disk_schedules_on_earliest_free_slot():
    disk = VirtualDisk(LAT)  # 2 slots, 10s per load
    assert disk.schedule(0.0) == (0.0, 10.0)
    assert disk.schedule(0.0) == (0.0, 10.0)  # second slot
    assert disk.schedule(0.0) == (10.0, 20.0)  # queues behind the first
    assert disk.schedule(25.0) == (25.0, 35.0)  # idle gap: starts on request
    assert disk.loads == 4
    assert disk.busy_seconds == pytest.approx(40.0)


# ---------------------------------------------------------------------------
# virtual-clock replay: hand-built traces with known stall arithmetic
# ---------------------------------------------------------------------------


def test_method_entry_prediction_arrives_timely():
    """3-event trace: enter predicts b a whole access ahead, so b's load
    (0 -> 10) lands before its need (t=11): one timely hit, and the only
    stall is a's unpredicted demand load."""
    store, (a, b) = _store_with(2)
    trace = RecordedTrace("t", "m", [("enter", "Obj.m", a), ("access", a), ("access", b)], [a, b])
    res = replay(trace, Scripted(on_entry=[b]), store, None, latency=LAT)
    # access a: demand load 0 -> 10 (stall 10), think -> 11
    # access b: prefetched load completed at 10 <= 11 -> timely, no stall
    assert res.stall_seconds == pytest.approx(10.0)
    assert res.timely_coverage == pytest.approx(0.5)
    assert res.partial_hide == 0.0
    assert res.overhead["hidden_seconds"] == pytest.approx(10.0)
    assert res.overhead["late_predictions"] == 0
    # baseline pays both demand loads: 10 + 10
    assert res.baseline_stall_seconds == pytest.approx(20.0)
    assert res.stall_saved_pct == pytest.approx(50.0)


def test_access_chained_prediction_only_partially_hides():
    """Predicting b only upon accessing a (miner-style, one access of lead)
    leaves the load in flight at need: the app waits out the remainder."""
    store, (a, b) = _store_with(2)
    trace = RecordedTrace("t", "m", [("access", a), ("access", b)], [a, b])
    res = replay(trace, Scripted(on_access_map={a: [b]}), store, None, latency=LAT)
    # access a: demand 0 -> 10 (stall 10), think -> 11; b predicted at 11,
    # load 11 -> 21; access b needed at 11: in flight -> stall 21-11 = 10
    assert res.stall_seconds == pytest.approx(20.0)
    assert res.timely_coverage == 0.0
    assert res.partial_hide == pytest.approx(0.5)
    assert res.overhead["late_predictions"] == 1
    assert res.coverage == pytest.approx(0.5)  # order-aware coverage ignores lateness


def test_demand_load_queues_behind_prefetch_on_one_disk_arm():
    """With a single slot per service, an over-eager prefetch delays the
    application's own demand load — the congestion cost the wall-clock
    benchmarks pay for real."""
    store, (a, b) = _store_with(2)
    lat1 = LatencyModel(disk_load=10.0, remote_hop=0.0, write_back=0.0, think=1.0,
                        parallel_per_ds=1)
    trace = RecordedTrace("t", "m", [("enter", "Obj.m", a), ("access", a), ("access", b)], [a, b])
    res = replay(trace, Scripted(on_entry=[b]), store, None, latency=lat1)
    # b's prefetch takes the only slot (0 -> 10); a's demand load queues
    # (10 -> 20): stall 20, then b is long since resident (timely)
    assert res.stall_seconds == pytest.approx(20.0)
    assert res.timely_coverage == pytest.approx(0.5)


def test_remote_hop_advances_the_needed_at_clock():
    """Objects on different services charge execution redirection before
    the load: needed-at includes the hop, exactly like the live store."""
    lat = LatencyModel(disk_load=10.0, remote_hop=3.0, write_back=0.0, think=1.0,
                       parallel_per_ds=2)
    store, _ = _store_with(0, n_services=2)
    a = store.put("Obj", {}, ds=0)
    b = store.put("Obj", {}, ds=1)
    trace = RecordedTrace("t", "m", [("access", a), ("access", b)], [a, b])
    engine = replay_baseline(trace, store, latency=lat)
    # hop (3) + load a (3 -> 13) + think -> 14; hop (-> 17) + load b (17 -> 27)
    assert engine.remote_hops == 2
    assert engine.stall_seconds == pytest.approx(20.0)
    assert engine.t == pytest.approx(28.0)


# ---------------------------------------------------------------------------
# bounded cache: evictions, thrash, the capacity sweep
# ---------------------------------------------------------------------------


def test_bounded_cache_counts_thrash_and_unused_prefetch_evictions():
    store, (a, b, u) = _store_with(3)
    events = [("enter", "Obj.m", a), ("access", a), ("access", b), ("access", a)]
    trace = RecordedTrace("t", "m", events, [a, b, a])
    res = replay(trace, Scripted(on_entry=[u]), store, None, latency=LAT, cache_capacity=1)
    # u's useless prefetch lands and immediately evicts a; b then evicts u
    # (never used); re-accessing a is a full miss caused by eviction
    assert res.evictions >= 2
    assert res.overhead["evicted_before_use"] == 1
    assert res.thrash_misses == 1
    assert res.false_positives == 1  # u was never accessed


def test_unbounded_cache_never_evicts_and_rereads_hit():
    store, (a, b) = _store_with(2)
    trace = RecordedTrace("t", "m", [("access", a), ("access", b), ("access", a)], [a, b, a])
    engine = replay_baseline(trace, store, latency=LAT, cache_capacity=0)
    assert engine.evictions == 0 and engine.thrash_misses == 0
    assert engine.stall_seconds == pytest.approx(20.0)  # only the two cold misses


def test_cache_capacity_sweep_produces_one_row_per_capacity():
    wl = _catalog()["bank"]
    results = evaluate_workload(wl, modes=("capre",), cache_capacities=(0, 8))
    assert [r.cache_capacity for r in results] == [0, 8]
    unbounded, tiny = results
    assert unbounded.evictions == 0
    # bank's working set (~250 objects over 4 services) cannot fit in 8
    # slots per service: the bounded run must evict and stall more
    assert tiny.evictions > 0
    assert tiny.stall_seconds > unbounded.stall_seconds


# ---------------------------------------------------------------------------
# the write path: VirtualDisk occupancy, replay arithmetic, store accounting
# ---------------------------------------------------------------------------

# disk_load=10, write_back=4, think=1, ONE slot: flush delays are exact
LATW = LatencyModel(disk_load=10.0, remote_hop=0.0, write_back=4.0, think=1.0,
                    parallel_per_ds=1)


def test_virtual_disk_write_back_occupies_the_same_slots():
    disk = VirtualDisk(LATW)  # 1 slot: loads queue behind flushes
    assert disk.schedule(0.0) == (0.0, 10.0)
    assert disk.schedule_write_back(10.0) == (10.0, 14.0)
    assert disk.schedule(10.0) == (14.0, 24.0)  # queues behind the flush
    assert disk.loads == 2 and disk.write_backs == 1
    assert disk.busy_seconds == pytest.approx(24.0)


def test_dirty_eviction_flush_delays_queued_loads():
    """Hand-built mutating trace, capacity 1: the dirty line's flush
    occupies the only disk arm, so the re-load of the evicted object
    stalls for load + residual flush time.

      write a : write-allocate 0->10 (stall 10), dirty, think -> 11
      access b: demand 11->21 (stall 10); inserting b evicts dirty a,
                flush occupies the slot 21->25
      access a: needed at 22, load queues behind the flush 25->35
                (stall 13 = 10 load + 3 residual flush)"""
    store, (a, b) = _store_with(2)
    trace = RecordedTrace("t", "m", [("write", a), ("access", b), ("access", a)], [a, b, a])
    engine = replay_baseline(trace, store, latency=LATW, cache_capacity=1)
    assert engine.writes == 1 and engine.write_hits == 0
    assert engine.dirty_evictions == 1 and engine.flushed_writes == 1
    assert engine.stall_seconds == pytest.approx(33.0)
    assert engine.thrash_misses == 1


def test_write_hit_dirties_without_stalling():
    store, (a,) = _store_with(1)
    trace = RecordedTrace("t", "m", [("access", a), ("write", a)], [a, a])
    engine = replay_baseline(trace, store, latency=LATW)
    # only the cold read stalls; the write finds the line resident
    assert engine.stall_seconds == pytest.approx(10.0)
    assert engine.writes == 1 and engine.write_hits == 1
    assert engine.flushed_writes == 0  # unbounded cache: never evicted


def test_prefetched_write_counts_timely():
    """A write to an object prefetching made resident is a timely hit —
    write-allocate was hidden exactly like a read's demand load."""
    store, (a, b) = _store_with(2)
    trace = RecordedTrace("t", "m",
                          [("enter", "Obj.m", a), ("access", a), ("write", b)], [a, b])
    res = replay(trace, Scripted(on_entry=[b]), store, None, latency=LAT)
    # a: demand 0->10 (stall 10); b: prefetched load done at 10 <= 11
    assert res.stall_seconds == pytest.approx(10.0)
    assert res.timely_coverage == pytest.approx(0.5)
    assert res.writes == 1 and res.write_hits == 1
    assert res.recall == pytest.approx(0.5)  # written oids count as demand


def test_store_write_allocate_and_dirty_accounting():
    """ObjectStore.app_write is a demand access: write-allocate miss,
    dirty bit, accessed_oids, listeners, trace — none of which it used
    to touch."""
    store = ObjectStore(n_services=1)
    ds = store.services[0]
    a = store.put("X", {"v": 1})
    missed, seen = [], []
    store.miss_listener = missed.append
    store.access_listener = seen.append
    store.trace = []
    store.app_write(a)  # uncached: the write performs the disk load
    m = store.metrics
    assert m.writes == 1 and m.write_hits == 0 and m.app_cache_misses == 1
    assert ds.is_cached(a) and a in ds.dirty
    assert a in store.accessed_oids
    assert missed == [a] and seen == [a]
    assert [(e.kind, e.oid) for e in store.trace] == [("write", a)]
    store.app_write(a)  # resident: write hit, no second miss
    assert store.metrics.write_hits == 1 and store.metrics.writes == 2
    assert store.metrics.app_cache_misses == 1
    assert missed == [a] and seen == [a, a]
    ds.drop_cache()  # flushes the dirty line (charges write_back)
    assert ds.flushed_writes == 1 and not ds.dirty
    assert store.metrics.flushed_writes == 1


def test_credit_all_primitive_writes_hit_resident_lines():
    """The write-dense bank traversal: every transaction is navigated and
    then updated in place, so each primitive-field write is a write hit on
    the line the read just loaded — no extra misses, one dirty line per
    transaction."""
    client = POSClient(n_services=2)
    client.register(build_bank_app())
    root = populate_bank_store(client.store, n_transactions=12)
    with client.session("bank", mode=None) as s:
        s.execute(root, "creditAll", 5.0)
    m = client.store.metrics
    assert m.writes == 12 and m.write_hits == 12
    dirty = sum(len(ds.dirty) for ds in client.store.services)
    assert dirty == 12
    txs = client.store.peek(root).fields["transactions"]
    assert all(
        client.store.peek(t).fields["amount"] == pytest.approx(i + 5.0)
        for i, t in enumerate(txs)
    )


def test_store_dirty_eviction_flushes_write_back():
    store = ObjectStore(n_services=1, cache_capacity=1)
    ds = store.services[0]
    a = store.put("X", {})
    b = store.put("X", {})
    store.app_write(a)
    ds.load_into_memory(b)  # evicts dirty a -> flush
    assert ds.evictions == 1
    assert ds.dirty_evictions == 1 and ds.flushed_writes == 1
    assert store.metrics.dirty_evictions == 1 and store.metrics.flushed_writes == 1
    assert a not in ds.dirty


def test_reset_runtime_state_clears_eviction_counters():
    """Regression: DataService.evictions survived reset_runtime_state and
    accumulated across benchmark repetitions."""
    store = ObjectStore(n_services=1, cache_capacity=1)
    ds = store.services[0]
    oids = [store.put("X", {}) for _ in range(3)]
    for o in oids:
        ds.load_into_memory(o)
    assert ds.evictions == 2
    store.reset_runtime_state()
    assert ds.evictions == 0
    assert ds.dirty_evictions == 0 and ds.flushed_writes == 0
    for o in oids:
        ds.load_into_memory(o)
    assert ds.evictions == 2  # fresh count, not 4


def test_second_session_preserves_first_sessions_listeners():
    """Regression: opening (and closing) a second session used to clobber
    the first session's predictor monitoring hooks."""
    client = POSClient(n_services=2)
    client.register(build_bank_app())
    populate_bank_store(client.store, n_transactions=5)
    s1 = client.session("bank", mode="markov-miner")
    miner_listener = client.store.access_listener
    assert miner_listener is not None
    try:
        with client.session("bank", mode=None):
            assert client.store.access_listener is miner_listener
        assert client.store.access_listener is miner_listener
        # a rop session installs only its miss listener, and removes only it
        with client.session("bank", mode="rop"):
            assert client.store.miss_listener is not None
            assert client.store.access_listener is miner_listener
        assert client.store.miss_listener is None
        assert client.store.access_listener is miner_listener
        # a second miner displaces the hook for its lifetime, then restores
        with client.session("bank", mode="markov-miner"):
            assert client.store.access_listener is not miner_listener
        assert client.store.access_listener is miner_listener
    finally:
        s1.close()
    assert client.store.access_listener is None


def test_non_lifo_session_close_never_resurrects_dead_listeners():
    """Closing sessions out of LIFO order must not reinstall a hook whose
    predictor already unbound: a zombie miner listener would keep charging
    monitoring on every access with no session left to remove it."""
    client = POSClient(n_services=2)
    client.register(build_bank_app())
    populate_bank_store(client.store, n_transactions=5)
    s1 = client.session("bank", mode="markov-miner")
    s2 = client.session("bank", mode="markov-miner")
    s1.close()  # s2's hook is installed; s1 removes nothing, restores nothing
    assert client.store.access_listener is not None
    s2.close()  # must NOT restore s1's now-dead hook
    assert client.store.access_listener is None
    assert client.store.miss_listener is None


def test_markov_warm_accepts_event_and_bare_oid_traces():
    from repro.predict.markov import MarkovMiner

    events = [
        TraceEvent("access", 1),
        TraceEvent("method_entry", 1, "X.m"),  # skipped: not a demand event
        TraceEvent("write", 2),  # writes are part of the mined stream
        TraceEvent("access", 3),
    ]
    assert trace_oids(events) == [1, 2, 3]
    m_events, m_oids = MarkovMiner(), MarkovMiner()
    m_events.warm(events)
    m_oids.warm([1, 2, 3])
    assert m_events._table == m_oids._table


def test_bank_write_workload_scores_writes_for_all_predictors():
    """The acceptance bar: the mutating bank traversal is recorded with
    write events and every predictor gets timeliness rows with the write
    path charged."""
    wl = _catalog()["bank_write"]
    results = evaluate_workload(wl, modes=("capre", "markov-miner"), cache_capacities=(64,))
    assert {r.predictor for r in results} == {"static-capre", "markov-miner"}
    for r in results:
        assert r.workload == "setAllTransCustomers"
        assert r.writes > 0  # the setCustomer updates were replayed
        assert r.baseline_stall_seconds > 0
        assert 0.0 <= r.timely_coverage <= 1.0
    by = {r.predictor: r for r in results}
    # method-entry lead hides disk loads on the mutating traversal too
    assert by["static-capre"].stall_seconds < by["static-capre"].baseline_stall_seconds


# ---------------------------------------------------------------------------
# the paper's claim, now measurable
# ---------------------------------------------------------------------------


def test_static_capre_beats_markov_on_timely_coverage_for_collections():
    """Order-aware coverage ties static-capre and the miner (~1.0 both);
    the virtual clock separates them: method-entry lead hides the disk,
    access-chained lead does not (kmeans is the collection-heavy app)."""
    results = {r.predictor: r for r in evaluate_workload(
        _catalog()["kmeans"], modes=("capre", "markov-miner"), cache_capacities=(64,)
    )}
    capre, markov = results["static-capre"], results["markov-miner"]
    assert capre.coverage == pytest.approx(markov.coverage, abs=0.05)  # the old metric ties
    assert capre.timely_coverage > markov.timely_coverage + 0.1  # the new one does not
    assert capre.stall_seconds < markov.stall_seconds


# ---------------------------------------------------------------------------
# parallel recording + artifacts
# ---------------------------------------------------------------------------


def test_record_catalog_matches_serial_recording():
    catalog = _catalog()
    wls = [catalog["bank"], catalog["wordcount"]]
    recorded = record_catalog(wls, runs=1)
    assert set(recorded) == {"bank", "wordcount"}
    _, _, serial = record_workload(catalog["bank"], runs=1)
    _, _, parallel = recorded["bank"]
    assert parallel[0].events == serial[0].events
    assert parallel[0].accesses == serial[0].accesses


def test_write_csv_round_trips_with_nan_safe_cells(tmp_path):
    # kmeans has no single associations: rop emits nothing, so its
    # precision is *undefined* and must land as an empty cell
    wl = _catalog()["kmeans"]
    results = evaluate_workload(wl, modes=("capre", "rop"), cache_capacities=(0,))
    path = write_csv(results, str(tmp_path / "predict" / "replay.csv"))
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    assert len(rows) == 2
    assert set(rows[0]) == set(CSV_COLUMNS)
    by_pred = {r["predictor"]: r for r in rows}
    assert float(by_pred["static-capre"]["timely_coverage"]) > 0.9
    assert by_pred["rop"]["precision"] == ""  # undefined, not a phantom 0.0
    assert by_pred["rop"]["evaluated"] == "False"
    assert float(by_pred["rop"]["recall"]) == 0.0  # defined: accesses happened


def test_compare_predict_gate_catches_drops_and_missing_rows(tmp_path):
    from benchmarks.compare_predict import compare

    header = ("app,workload,predictor,cache_capacity,policy,timely_coverage,"
              "stall_saved_pct,writes,write_hits,dirty_evictions,flushed_writes,"
              "protected_evictions,dispatch,batch_dispatches,dedup_suppressed,"
              "stall_p50_s,stall_p99_s,stall_p999_s,calib_scale,calibrated_stall_s,"
              "placement,replication,scenario,failovers,"
              "rfo_prefetches,truncated_hints,hint_priority_mean,"
              "ownership_upgrades,exec_delayed,write_quorum,readmissions,"
              "resync_lines,hedged_reads,hedge_wins,quorum_writes,"
              "quorum_acks,quorum_retries,quorum_failures\n")
    base = tmp_path / "baseline.csv"
    base.write_text(header
                    + "bank,auditAll,static-capre,64,lru,0.99,98.9,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n"
                    + "bank,auditAll,markov-miner,64,lru,0.50,89.8,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    ok = tmp_path / "ok.csv"
    ok.write_text(header
                  + "bank,auditAll,static-capre,64,lru,0.985,98.0,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n"
                  + "bank,auditAll,markov-miner,64,lru,0.55,90.0,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    assert compare(str(ok), str(base)) == []
    dropped = tmp_path / "dropped.csv"
    dropped.write_text(header + "bank,auditAll,static-capre,64,lru,0.80,80.0,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    failures = compare(str(dropped), str(base))
    assert len(failures) == 2  # the regression AND the vanished miner row
    assert any("0.800" in f and "static-capre" in f for f in failures)
    assert any("missing" in f and "markov-miner" in f for f in failures)
    empty = tmp_path / "empty_cell.csv"
    empty.write_text(header
                     + "bank,auditAll,static-capre,64,lru,,98.0,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n"
                     + "bank,auditAll,markov-miner,64,lru,0.55,90.0,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    assert any("empty" in f for f in compare(str(empty), str(base)))


def test_compare_predict_gate_enforces_write_columns(tmp_path):
    """A replay.csv produced by a write-blind harness (no write columns, or
    an emptied ``writes`` cell on a mutating row) fails the gate."""
    from benchmarks.compare_predict import compare

    header = ("app,workload,predictor,cache_capacity,policy,timely_coverage,"
              "stall_saved_pct,writes,write_hits,dirty_evictions,flushed_writes,"
              "protected_evictions,dispatch,batch_dispatches,dedup_suppressed,"
              "stall_p50_s,stall_p99_s,stall_p999_s,calib_scale,calibrated_stall_s,"
              "placement,replication,scenario,failovers,"
              "rfo_prefetches,truncated_hints,hint_priority_mean,"
              "ownership_upgrades,exec_delayed,write_quorum,readmissions,"
              "resync_lines,hedged_reads,hedge_wins,quorum_writes,"
              "quorum_acks,quorum_retries,quorum_failures\n")
    base = tmp_path / "baseline.csv"
    base.write_text(header + "bank,setAllTransCustomers,static-capre,64,lru,0.95,90.0,21,21,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    # (a) header without the write columns
    old_header = ("app,workload,predictor,cache_capacity,policy,timely_coverage,"
                  "stall_saved_pct,protected_evictions\n")
    blind = tmp_path / "blind.csv"
    blind.write_text(old_header + "bank,setAllTransCustomers,static-capre,64,lru,0.95,90.0,0\n")
    failures = compare(str(blind), str(base))
    assert any("write-path columns missing" in f for f in failures)
    # (b) columns present but the mutating row's writes cell went empty
    hollow = tmp_path / "hollow.csv"
    hollow.write_text(header + "bank,setAllTransCustomers,static-capre,64,lru,0.95,90.0,,,,,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    failures = compare(str(hollow), str(base))
    assert any("writes cell is empty" in f for f in failures)
    # (c) intact file passes
    good = tmp_path / "good.csv"
    good.write_text(header + "bank,setAllTransCustomers,static-capre,64,lru,0.96,91.0,21,21,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    assert compare(str(good), str(base)) == []


def test_update_baseline_refuses_to_shrink_the_gate(tmp_path, capsys):
    """--update-baseline must not promote a partial sweep: a fresh file
    missing rows the old baseline guarded fails unless --force."""
    from benchmarks.compare_predict import main

    header = ("app,workload,predictor,cache_capacity,policy,timely_coverage,"
              "stall_saved_pct,writes,write_hits,dirty_evictions,flushed_writes,"
              "protected_evictions,dispatch,batch_dispatches,dedup_suppressed,"
              "stall_p50_s,stall_p99_s,stall_p999_s,calib_scale,calibrated_stall_s,"
              "placement,replication,scenario,failovers,"
              "rfo_prefetches,truncated_hints,hint_priority_mean,"
              "ownership_upgrades,exec_delayed,write_quorum,readmissions,"
              "resync_lines,hedged_reads,hedge_wins,quorum_writes,"
              "quorum_acks,quorum_retries,quorum_failures\n")
    base = tmp_path / "baseline.csv"
    base.write_text(header
                    + "bank,auditAll,static-capre,64,lru,0.99,98.9,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n"
                    + "bank,auditAll,static-capre,64,prefetch-aware,0.99,98.9,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    partial = tmp_path / "partial.csv"
    partial.write_text(header + "bank,auditAll,static-capre,64,lru,0.99,98.9,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    assert main([str(partial), str(base), "--update-baseline"]) == 1
    assert "refusing to shrink" in capsys.readouterr().out
    assert "prefetch-aware" in base.read_text()  # untouched
    # --force promotes the shrink deliberately; a superset needs no force
    assert main([str(partial), str(base), "--update-baseline", "--force"]) == 0
    assert base.read_text() == partial.read_text()
    grown = tmp_path / "grown.csv"
    grown.write_text(partial.read_text()
                     + "bank,auditAll,static-capre,64,prefetch-aware,0.99,98.9,0,0,0,0,,per-oid,0,0,0.0,0.0,0.0,1.0,0.0\n")
    assert main([str(grown), str(base), "--update-baseline"]) == 0
    assert base.read_text() == grown.read_text()
