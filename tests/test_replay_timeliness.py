"""The timeliness-aware replay engine: virtual-clock stall arithmetic on
hand-built traces, disk-slot queueing, bounded-cache thrash accounting, the
cache-capacity sweep, parallel recording determinism, and the CSV artifact
shape (ISSUE 2 tentpole)."""

import csv

import pytest

from repro.pos.latency import REPLAY, LatencyModel, VirtualDisk
from repro.pos.store import ObjectStore
from repro.predict.base import Predictor
from repro.predict.evaluate import (
    CSV_COLUMNS,
    RecordedTrace,
    _catalog,
    evaluate_workload,
    record_catalog,
    record_workload,
    replay,
    replay_baseline,
    write_csv,
)

# disk_load=10, think=1: every stall below is exact integer arithmetic
LAT = LatencyModel(disk_load=10.0, remote_hop=0.0, write_back=0.0, think=1.0,
                   parallel_per_ds=2)


class Scripted(Predictor):
    """Emit a fixed oid list at method entry and/or per-access."""

    name = "scripted"

    def __init__(self, on_entry=(), on_access_map=None):
        super().__init__()
        self._on_entry = list(on_entry)
        self._on_access = dict(on_access_map or {})

    def on_method_entry(self, method_key, this_oid):
        return self._emit(list(self._on_entry))

    def on_access(self, oid, cls):
        return self._emit(list(self._on_access.get(oid, ())))


def _store_with(n_objects: int, n_services: int = 1) -> tuple[ObjectStore, list[int]]:
    store = ObjectStore(n_services=n_services)
    oids = [store.put("Obj", {}) for _ in range(n_objects)]
    return store, oids


# ---------------------------------------------------------------------------
# VirtualDisk slot arithmetic
# ---------------------------------------------------------------------------


def test_virtual_disk_schedules_on_earliest_free_slot():
    disk = VirtualDisk(LAT)  # 2 slots, 10s per load
    assert disk.schedule(0.0) == (0.0, 10.0)
    assert disk.schedule(0.0) == (0.0, 10.0)  # second slot
    assert disk.schedule(0.0) == (10.0, 20.0)  # queues behind the first
    assert disk.schedule(25.0) == (25.0, 35.0)  # idle gap: starts on request
    assert disk.loads == 4
    assert disk.busy_seconds == pytest.approx(40.0)


# ---------------------------------------------------------------------------
# virtual-clock replay: hand-built traces with known stall arithmetic
# ---------------------------------------------------------------------------


def test_method_entry_prediction_arrives_timely():
    """3-event trace: enter predicts b a whole access ahead, so b's load
    (0 -> 10) lands before its need (t=11): one timely hit, and the only
    stall is a's unpredicted demand load."""
    store, (a, b) = _store_with(2)
    trace = RecordedTrace("t", "m", [("enter", "Obj.m", a), ("access", a), ("access", b)], [a, b])
    res = replay(trace, Scripted(on_entry=[b]), store, None, latency=LAT)
    # access a: demand load 0 -> 10 (stall 10), think -> 11
    # access b: prefetched load completed at 10 <= 11 -> timely, no stall
    assert res.stall_seconds == pytest.approx(10.0)
    assert res.timely_coverage == pytest.approx(0.5)
    assert res.partial_hide == 0.0
    assert res.overhead["hidden_seconds"] == pytest.approx(10.0)
    assert res.overhead["late_predictions"] == 0
    # baseline pays both demand loads: 10 + 10
    assert res.baseline_stall_seconds == pytest.approx(20.0)
    assert res.stall_saved_pct == pytest.approx(50.0)


def test_access_chained_prediction_only_partially_hides():
    """Predicting b only upon accessing a (miner-style, one access of lead)
    leaves the load in flight at need: the app waits out the remainder."""
    store, (a, b) = _store_with(2)
    trace = RecordedTrace("t", "m", [("access", a), ("access", b)], [a, b])
    res = replay(trace, Scripted(on_access_map={a: [b]}), store, None, latency=LAT)
    # access a: demand 0 -> 10 (stall 10), think -> 11; b predicted at 11,
    # load 11 -> 21; access b needed at 11: in flight -> stall 21-11 = 10
    assert res.stall_seconds == pytest.approx(20.0)
    assert res.timely_coverage == 0.0
    assert res.partial_hide == pytest.approx(0.5)
    assert res.overhead["late_predictions"] == 1
    assert res.coverage == pytest.approx(0.5)  # order-aware coverage ignores lateness


def test_demand_load_queues_behind_prefetch_on_one_disk_arm():
    """With a single slot per service, an over-eager prefetch delays the
    application's own demand load — the congestion cost the wall-clock
    benchmarks pay for real."""
    store, (a, b) = _store_with(2)
    lat1 = LatencyModel(disk_load=10.0, remote_hop=0.0, write_back=0.0, think=1.0,
                        parallel_per_ds=1)
    trace = RecordedTrace("t", "m", [("enter", "Obj.m", a), ("access", a), ("access", b)], [a, b])
    res = replay(trace, Scripted(on_entry=[b]), store, None, latency=lat1)
    # b's prefetch takes the only slot (0 -> 10); a's demand load queues
    # (10 -> 20): stall 20, then b is long since resident (timely)
    assert res.stall_seconds == pytest.approx(20.0)
    assert res.timely_coverage == pytest.approx(0.5)


def test_remote_hop_advances_the_needed_at_clock():
    """Objects on different services charge execution redirection before
    the load: needed-at includes the hop, exactly like the live store."""
    lat = LatencyModel(disk_load=10.0, remote_hop=3.0, write_back=0.0, think=1.0,
                       parallel_per_ds=2)
    store, _ = _store_with(0, n_services=2)
    a = store.put("Obj", {}, ds=0)
    b = store.put("Obj", {}, ds=1)
    trace = RecordedTrace("t", "m", [("access", a), ("access", b)], [a, b])
    engine = replay_baseline(trace, store, latency=lat)
    # hop (3) + load a (3 -> 13) + think -> 14; hop (-> 17) + load b (17 -> 27)
    assert engine.remote_hops == 2
    assert engine.stall_seconds == pytest.approx(20.0)
    assert engine.t == pytest.approx(28.0)


# ---------------------------------------------------------------------------
# bounded cache: evictions, thrash, the capacity sweep
# ---------------------------------------------------------------------------


def test_bounded_cache_counts_thrash_and_unused_prefetch_evictions():
    store, (a, b, u) = _store_with(3)
    events = [("enter", "Obj.m", a), ("access", a), ("access", b), ("access", a)]
    trace = RecordedTrace("t", "m", events, [a, b, a])
    res = replay(trace, Scripted(on_entry=[u]), store, None, latency=LAT, cache_capacity=1)
    # u's useless prefetch lands and immediately evicts a; b then evicts u
    # (never used); re-accessing a is a full miss caused by eviction
    assert res.evictions >= 2
    assert res.overhead["evicted_before_use"] == 1
    assert res.thrash_misses == 1
    assert res.false_positives == 1  # u was never accessed


def test_unbounded_cache_never_evicts_and_rereads_hit():
    store, (a, b) = _store_with(2)
    trace = RecordedTrace("t", "m", [("access", a), ("access", b), ("access", a)], [a, b, a])
    engine = replay_baseline(trace, store, latency=LAT, cache_capacity=0)
    assert engine.evictions == 0 and engine.thrash_misses == 0
    assert engine.stall_seconds == pytest.approx(20.0)  # only the two cold misses


def test_cache_capacity_sweep_produces_one_row_per_capacity():
    wl = _catalog()["bank"]
    results = evaluate_workload(wl, modes=("capre",), cache_capacities=(0, 8))
    assert [r.cache_capacity for r in results] == [0, 8]
    unbounded, tiny = results
    assert unbounded.evictions == 0
    # bank's working set (~250 objects over 4 services) cannot fit in 8
    # slots per service: the bounded run must evict and stall more
    assert tiny.evictions > 0
    assert tiny.stall_seconds > unbounded.stall_seconds


# ---------------------------------------------------------------------------
# the paper's claim, now measurable
# ---------------------------------------------------------------------------


def test_static_capre_beats_markov_on_timely_coverage_for_collections():
    """Order-aware coverage ties static-capre and the miner (~1.0 both);
    the virtual clock separates them: method-entry lead hides the disk,
    access-chained lead does not (kmeans is the collection-heavy app)."""
    results = {r.predictor: r for r in evaluate_workload(
        _catalog()["kmeans"], modes=("capre", "markov-miner"), cache_capacities=(64,)
    )}
    capre, markov = results["static-capre"], results["markov-miner"]
    assert capre.coverage == pytest.approx(markov.coverage, abs=0.05)  # the old metric ties
    assert capre.timely_coverage > markov.timely_coverage + 0.1  # the new one does not
    assert capre.stall_seconds < markov.stall_seconds


# ---------------------------------------------------------------------------
# parallel recording + artifacts
# ---------------------------------------------------------------------------


def test_record_catalog_matches_serial_recording():
    catalog = _catalog()
    wls = [catalog["bank"], catalog["wordcount"]]
    recorded = record_catalog(wls, runs=1)
    assert set(recorded) == {"bank", "wordcount"}
    _, _, serial = record_workload(catalog["bank"], runs=1)
    _, _, parallel = recorded["bank"]
    assert parallel[0].events == serial[0].events
    assert parallel[0].accesses == serial[0].accesses


def test_write_csv_round_trips_with_nan_safe_cells(tmp_path):
    # kmeans has no single associations: rop emits nothing, so its
    # precision is *undefined* and must land as an empty cell
    wl = _catalog()["kmeans"]
    results = evaluate_workload(wl, modes=("capre", "rop"), cache_capacities=(0,))
    path = write_csv(results, str(tmp_path / "predict" / "replay.csv"))
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    assert len(rows) == 2
    assert set(rows[0]) == set(CSV_COLUMNS)
    by_pred = {r["predictor"]: r for r in rows}
    assert float(by_pred["static-capre"]["timely_coverage"]) > 0.9
    assert by_pred["rop"]["precision"] == ""  # undefined, not a phantom 0.0
    assert by_pred["rop"]["evaluated"] == "False"
    assert float(by_pred["rop"]["recall"]) == 0.0  # defined: accesses happened


def test_compare_predict_gate_catches_drops_and_missing_rows(tmp_path):
    from benchmarks.compare_predict import compare

    header = "app,workload,predictor,cache_capacity,timely_coverage,stall_saved_pct\n"
    base = tmp_path / "baseline.csv"
    base.write_text(header
                    + "bank,auditAll,static-capre,64,0.99,98.9\n"
                    + "bank,auditAll,markov-miner,64,0.50,89.8\n")
    ok = tmp_path / "ok.csv"
    ok.write_text(header
                  + "bank,auditAll,static-capre,64,0.985,98.0\n"
                  + "bank,auditAll,markov-miner,64,0.55,90.0\n")
    assert compare(str(ok), str(base)) == []
    dropped = tmp_path / "dropped.csv"
    dropped.write_text(header + "bank,auditAll,static-capre,64,0.80,80.0\n")
    failures = compare(str(dropped), str(base))
    assert len(failures) == 2  # the regression AND the vanished miner row
    assert any("0.800" in f and "static-capre" in f for f in failures)
    assert any("missing" in f and "markov-miner" in f for f in failures)
    empty = tmp_path / "empty_cell.csv"
    empty.write_text(header
                     + "bank,auditAll,static-capre,64,,98.0\n"
                     + "bank,auditAll,markov-miner,64,0.55,90.0\n")
    assert any("empty" in f for f in compare(str(empty), str(base)))
