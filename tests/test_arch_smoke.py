"""Per-architecture smoke tests: a REDUCED config of the same family runs
one train step (loss + grads) and one prefill->decode chain on CPU,
asserting output shapes and the absence of NaNs.  The FULL configs are
exercised only by the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import Model

B, S = 2, 16


def _batch(cfg, rng):
    kt, ke, kf = jax.random.split(rng, 3)
    batch = {
        "inputs": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
    }
    if cfg.embeds_input:
        batch["embeds"] = jax.random.normal(ke, (B, S, cfg.d_model), jnp.float32)
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kf, (B, cfg.enc_positions, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch).replace(attn_impl="chunked", attn_chunk=8, remat="none")
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    batch = _batch(cfg, rng)

    loss, grads = jax.jit(jax.value_and_grad(lambda p: model.loss_fn(p, batch)))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss is not finite"
    assert float(loss) > 0
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32)**2) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: grad norm not finite"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_then_decode(arch):
    cfg = get_smoke_config(arch).replace(attn_impl="chunked", attn_chunk=8, remat="none")
    model = Model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init_params(rng)
    batch = _batch(cfg, rng)

    logits, cache = jax.jit(lambda p, b: model.prefill(p, b))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: prefill logits NaN"

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache2 = jax.jit(lambda p, c, t: model.decode_step(p, c, t, S))(params, cache, tok)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), f"{arch}: decode logits NaN"
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_dimensions(arch):
    """The FULL configs carry the exact published dimensions (no allocation:
    template/abstract only)."""
    cfg = get_config(arch)
    model = Model(cfg)
    n = cfg.param_count()
    assert n > 0
    abstract = model.abstract_params()
    # vocab rows padded to a multiple of 256 for even TP sharding
    vp = abstract["embed"].shape[0]
    assert vp % 256 == 0 and cfg.vocab_size <= vp < cfg.vocab_size + 256
    assert abstract["embed"].shape[1] == cfg.d_model


EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
    "chatglm3_6b": (28, 4096, 32, 2, 13696, 65024),
    "yi_34b": (60, 7168, 56, 8, 20480, 64000),
    "qwen1_5_4b": (40, 2560, 20, 20, 6912, 151936),
    "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
    "qwen2_vl_2b": (28, 1536, 12, 2, 8960, 151936),
    "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
    "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936),
    "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
    "falcon_mamba_7b": (64, 4096, 1, 1, 0, 65024),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_dimensions_match_spec(arch):
    cfg = get_config(arch)
    exp = EXPECTED[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size) == exp


def test_moe_param_counts_plausible():
    q = get_config("qwen3_moe_30b_a3b")
    total, active = q.param_count(), q.active_param_count()
    assert 25e9 < total < 36e9, f"qwen3-moe total {total/1e9:.1f}B"
    assert 2e9 < active < 5e9, f"qwen3-moe active {active/1e9:.1f}B"
    g = get_config("granite_moe_1b_a400m")
    assert 0.8e9 < g.param_count() < 1.8e9
    assert 0.2e9 < g.active_param_count() < 0.8e9


def test_dense_param_counts_plausible():
    assert 30e9 < get_config("yi_34b").param_count() < 40e9
    assert 6e9 < get_config("falcon_mamba_7b").param_count() < 9e9
    assert 5.5e9 < get_config("chatglm3_6b").param_count() < 8e9
