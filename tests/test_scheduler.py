"""Continuous-batching scheduler tests: correctness vs the sequential
generate path, slot churn, and draining."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import Model
from repro.runtime.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("yi_34b")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _sequential_generate(model, params, prompt, n):
    """Reference: plain prefill + single-sequence decode loop."""
    batch = {"inputs": jnp.asarray(prompt, jnp.int32)[None]}
    logits, cache = model.prefill(params, batch)
    max_len = len(prompt) + n + 1
    pad = max_len - cache["k"].shape[2]
    for key in ("k", "v"):
        cache[key] = jnp.pad(cache[key], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for i in range(n - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, cache, tok, pos)
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return out


def test_batcher_matches_sequential(setup):
    cfg, model, params = setup
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=s).astype(np.int32) for s in (6, 9, 4)]
    n_new = 5

    expected = [_sequential_generate(model, params, p, n_new) for p in prompts]

    batcher = ContinuousBatcher(model, params, batch_size=2, max_len=32)
    for i, p in enumerate(prompts):
        batcher.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    finished = batcher.run_until_drained()
    assert len(finished) == 3
    got = {r.rid: r.output for r in finished}
    for i, exp in enumerate(expected):
        assert got[i] == exp, f"request {i}: {got[i]} != {exp}"


def test_batcher_slot_churn_more_requests_than_slots(setup):
    cfg, model, params = setup
    rng = np.random.RandomState(1)
    reqs = [
        Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, size=5).astype(np.int32),
                max_new_tokens=3 + (i % 3))
        for i in range(5)
    ]
    batcher = ContinuousBatcher(model, params, batch_size=2, max_len=24)
    for r in reqs:
        batcher.submit(r)
    finished = batcher.run_until_drained()
    assert {r.rid for r in finished} == set(range(5))
    for r in finished:
        assert len(r.output) == r.max_new_tokens
    # continuous batching: total decode steps far below sequential sum
    assert batcher.steps < sum(r.max_new_tokens for r in reqs)


def test_batcher_eos_stops_early(setup):
    cfg, model, params = setup
    rng = np.random.RandomState(2)
    prompt = rng.randint(0, cfg.vocab_size, size=6).astype(np.int32)
    # find the greedy first token, then use it as the EOS for the next request
    probe = Request(rid=0, prompt=prompt, max_new_tokens=4)
    b1 = ContinuousBatcher(model, params, batch_size=1, max_len=24)
    b1.submit(probe)
    b1.run_until_drained()
    eos = probe.output[1]

    req = Request(rid=1, prompt=prompt, max_new_tokens=10, eos_id=eos)
    b2 = ContinuousBatcher(model, params, batch_size=1, max_len=24)
    b2.submit(req)
    b2.run_until_drained()
    assert req.output[1] == eos
    assert len(req.output) == 2  # stopped at EOS, not max_new_tokens
