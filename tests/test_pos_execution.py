"""Integration tests: the bank application executing in the distributed POS
under the three prefetching modes (none / CAPre / ROP)."""

import time

import pytest

from repro.apps.bank import build_bank_app, populate_bank_store
from repro.pos.client import POSClient
from repro.pos.latency import ZERO, LatencyModel


@pytest.fixture()
def client():
    c = POSClient(n_services=4, latency=ZERO)
    c.register(build_bank_app())
    return c


def _run(client, mode=None, rop_depth=1, n_tx=60):
    root = populate_bank_store(client.store, n_transactions=n_tx)
    with client.session("bank", mode=mode, rop_depth=rop_depth) as s:
        s.execute(root, "setAllTransCustomers")
        assert s.drain(10.0)
    return root


def test_execution_semantics_updates_customers(client):
    """setAllTransCustomers sets the account's customer to the manager, but
    only for customers of the same company (the Listing 1 security check)."""
    root = _run(client, mode=None)
    store = client.store
    mgr = store.peek(root).fields["manager"]
    mgr_co = store.peek(mgr).fields["company"]
    for tx in store.peek(root).fields["transactions"]:
        acct = store.peek(store.peek(tx).fields["account"])
        cust = store.peek(acct.fields["cust"])
        if cust.fields["company"] == mgr_co:
            assert acct.fields["cust"] == mgr or cust.fields["name"] == "manager"


def test_capre_prefetch_covers_accessed_objects(client):
    """On the read-only traversal, CAPre predicts every object the
    application navigates (perfect recall, modulo the root it starts from)."""
    root = populate_bank_store(client.store, n_transactions=60)
    with client.session("bank", mode="capre") as s:
        s.execute(root, "auditAll")
        assert s.drain(10.0)
    accessed = client.store.accessed_oids - {root}
    prefetched = client.store.prefetched_oids
    missing = accessed - prefetched
    assert not missing, f"CAPre failed to predict {len(missing)} accessed objects"
    acc = client.store.prefetch_accuracy()
    assert acc["recall"] >= 0.99


def test_capre_prefetch_on_mutating_traversal_still_high_recall(client):
    """setAllTransCustomers mutates account.cust while the prefetcher runs;
    objects replaced before the prefetcher reaches them may be missed, but
    coverage stays high and every miss is a Customer that was swapped out."""
    root = _run(client, mode="capre")
    missing = (client.store.accessed_oids - {root}) - client.store.prefetched_oids
    assert all(client.store.cls_of(o) == "Customer" for o in missing)


def test_rop_never_prefetches_collections(client):
    """ROP only follows single associations: the Transaction objects (reached
    through the transactions collection) are never prefetched by ROP."""
    root = _run(client, mode="rop", rop_depth=5)
    store = client.store
    tx_oids = set(store.peek(root).fields["transactions"])
    assert not (store.prefetched_oids & tx_oids)


def test_rop_depth_increases_coverage(client):
    r1 = _run(client, mode="rop", rop_depth=1)
    cov1 = len(client.store.prefetched_oids)
    client.store.reset_runtime_state()
    with client.session("bank", mode="rop", rop_depth=3) as s:
        s.execute(r1, "setAllTransCustomers")
        assert s.drain(10.0)
    cov3 = len(client.store.prefetched_oids)
    assert cov3 >= cov1


def test_capre_wall_clock_beats_no_prefetch():
    """With realistic latencies, CAPre's parallel prefetching reduces the
    execution time of the collection-heavy traversal (paper section 7.2)."""
    lat = LatencyModel(disk_load=400e-6, remote_hop=80e-6, write_back=200e-6, think=80e-6)
    times = {}
    for mode in (None, "capre"):
        client = POSClient(n_services=4, latency=lat)
        client.register(build_bank_app())
        root = populate_bank_store(client.store, n_transactions=150)
        with client.session("bank", mode=mode, parallel_workers=16) as s:
            t0 = time.perf_counter()
            s.execute(root, "setAllTransCustomers")
            times[mode] = time.perf_counter() - t0
            s.drain(10.0)
    assert times["capre"] < times[None], f"capre {times['capre']:.3f}s !< none {times[None]:.3f}s"


def test_metrics_accounting(client):
    _run(client, mode=None, n_tx=20)
    m = client.store.snapshot_metrics()
    assert m["app_loads"] > 0
    assert m["app_cache_misses"] > 0
    assert m["prefetch_loads"] == 0  # no prefetching configured
    assert m["batch_dispatches"] == 0
    assert m["writes"] > 0  # the setCustomer updates
