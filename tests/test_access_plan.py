"""Tests for the JAX CAPre adaptation: jaxpr access analysis -> prefetch
plans -> weight streaming (the tensor-store analogue of sections 4-5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.access_plan import build_access_plan, rop_plan
from repro.models.model import Model
from repro.runtime.prefetch import HostParamStore, WeightStreamer


def _toy_params():
    return {
        "embed": jnp.ones((32, 8)),
        "layers": {"w": jnp.ones((4, 8, 8)), "b": jnp.ones((4, 8))},
        "head": jnp.ones((8, 32)),
        "unused": jnp.ones((16,)),
    }


def _toy_step(params, x):
    h = jnp.take(params["embed"], x, axis=0)

    def body(c, lp):
        return jnp.tanh(c @ lp["w"] + lp["b"]), None

    h, _ = jax.lax.scan(body, h, params["layers"])
    return h @ params["head"]


def test_plan_detects_scan_collections_and_order():
    params = _toy_params()
    plan = build_access_plan(_toy_step, params, jnp.zeros((4,), jnp.int32))
    by_path = {r.path: r for r in plan.records}
    # scanned stacked layers are collections (CAPre: the loop accesses all
    # elements -> prefetch the whole collection)
    assert by_path["layers.w"].collection
    assert by_path["layers.b"].collection
    assert not by_path["embed"].collection
    # program order: embed before layers before head
    assert by_path["embed"].first_use < by_path["layers.w"].first_use < by_path["head"].first_use
    # unused params never appear (no false positives — unlike ROP)
    assert "unused" not in by_path


def test_plan_marks_branch_dependent_cond():
    """lax.cond branches = the paper's branch-dependent navigations: params
    used in only one branch are marked; params used in both are not."""

    def step(params, x, flag):
        def t_branch(p, x):
            return x @ p["wa"] + x @ p["wc"]

        def f_branch(p, x):
            return x @ p["wb"] + x @ p["wc"]

        return jax.lax.cond(flag, t_branch, f_branch, params, x)

    params = {"wa": jnp.ones((4, 4)), "wb": jnp.ones((4, 4)), "wc": jnp.ones((4, 4))}
    plan = build_access_plan(step, params, jnp.ones((2, 4)), jnp.array(True))
    by_path = {r.path: r for r in plan.records}
    assert by_path["wa"].branch_dependent
    assert by_path["wb"].branch_dependent
    # union-of-branches promotion: wc is used in every branch
    assert not by_path["wc"].branch_dependent


def test_plan_on_real_model_decode():
    """The decode step of a real (reduced) architecture yields a plan whose
    collections are the stacked layer parameters."""
    cfg = get_smoke_config("chatglm3_6b")
    model = Model(cfg)
    params = model.abstract_params()  # no allocation — compile-time analysis
    cache = model.abstract_cache(2, 16)

    plan = build_access_plan(
        lambda p, c, t: model.decode_step(p, c, t, 8),
        params,
        cache,
        jax.ShapeDtypeStruct((2, 1), jnp.int32),
    )
    colls = {r.path for r in plan.collections()}
    assert any(p.startswith("layers.attn") for p in colls)
    by_path = {r.path: r for r in plan.records}
    assert by_path["embed"].first_use < by_path["final_norm"].first_use


def test_rop_plan_never_includes_collections_usefully():
    params = _toy_params()
    plan = build_access_plan(_toy_step, params, jnp.zeros((4,), jnp.int32))
    rp = rop_plan(params, depth_groups=2)
    # ROP takes the first groups in schema order, blind to the program:
    # it may fetch 'unused' and cannot know the scan consumes all layers
    assert all(not r.collection for r in rp.records)


def test_weight_streaming_capre_beats_rop_and_none():
    cfg = get_smoke_config("yi_34b").replace(n_layers=8)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    plan = build_access_plan(
        lambda p, c, t: model.decode_step(p, c, t, 8),
        model.abstract_params(),
        model.abstract_cache(2, 16),
        jax.ShapeDtypeStruct((2, 1), jnp.int32),
    )
    walls = {}
    metrics = {}
    for mode in (None, "rop", "capre"):
        store = HostParamStore(params, bandwidth_gbps=2.0, base_latency_s=500e-6)
        ws = WeightStreamer(store, plan=plan, mode=mode, k_ahead=3, workers=8)
        walls[mode] = ws.run_plan(compute_s_per_group=2e-3)
        metrics[mode] = ws.metrics
        ws.close()
    assert walls["capre"] < walls[None], walls
    assert walls["capre"] < walls["rop"], walls
    # the plan-driven mode overlaps almost everything
    assert metrics["capre"].prefetch_hits > metrics["rop"].prefetch_hits


def test_streaming_correctness_all_params_served():
    cfg = get_smoke_config("granite_moe_1b_a400m")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    plan = build_access_plan(
        lambda p, b: model.loss_fn(p, b),
        model.abstract_params(),
        {
            "inputs": jax.ShapeDtypeStruct((2, 8), jnp.int32),
            "targets": jax.ShapeDtypeStruct((2, 8), jnp.int32),
        },
    )
    store = HostParamStore(params, bandwidth_gbps=50.0, base_latency_s=1e-5)
    ws = WeightStreamer(store, plan=plan, mode="capre", k_ahead=2)
    seen = {}

    def compute(gi, arrays):
        seen.update({k: v.shape for k, v in arrays.items()})

    ws.run_plan(compute_fn=compute)
    ws.close()
    # every planned record was served with the right shape
    for rec in plan.records:
        assert seen[rec.path] == rec.shape
