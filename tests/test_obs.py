"""The unified observability layer (ISSUE 6): histogram percentile
correctness vs numpy, registry lifecycle, span lifecycle invariants on both
clocks (including hard-drain and reset paths), wall-vs-virtual span-field
parity, Chrome-trace export schema validation, the stall-percentile replay
columns, the compare_predict tail gate, the calibration loader, and the
WeightStreamer dispatch A/B through the shared registry."""

import json
import math

import numpy as np
import pytest

from repro.obs import (
    Histogram,
    Meter,
    Observability,
    Registry,
    SpanError,
    Tracer,
    check_span_invariants,
    chrome_trace,
    full_lifecycle_phase_counts,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.obs.metrics import log_buckets
from repro.pos.client import POSClient, SessionConfig
from repro.predict import make_pos_predictor
from repro.predict.base import Overhead
from repro.predict.calibration import (
    Calibration,
    calibrated_model,
    load_calibration,
)
from repro.pos.latency import REPLAY, LatencyModel
from repro.predict.evaluate import (
    CSV_COLUMNS,
    _calibration_app_key,
    _catalog,
    record_workload,
    replay,
)

# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------


def test_exact_histogram_matches_numpy_percentiles():
    rng = np.random.default_rng(42)
    xs = rng.exponential(0.01, size=500)
    h = Histogram(exact=True)
    for x in xs:
        h.record(float(x))
    for q in (0.5, 0.9, 0.99, 0.999):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(xs, q * 100)), rel=1e-9
        )
    p50, p99, p999 = h.percentiles()
    assert p50 <= p99 <= p999


def test_bucketed_histogram_estimate_within_bucket_resolution():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(mean=-6.0, sigma=1.5, size=500)
    h = Histogram(exact=False)
    for x in xs:
        h.record(float(x))
    width = 10 ** (1 / 8)  # one bucket per 1/8 decade
    for q in (0.5, 0.9, 0.99):
        est = h.percentile(q)
        true = float(np.percentile(xs, q * 100))
        # the estimate is the geometric midpoint of the rank's bucket —
        # within two bucket widths of the exact quantile
        assert true / width**2 <= est <= true * width**2


def test_histogram_under_and_overflow():
    h = Histogram(lo=1e-6, hi=100.0)
    for _ in range(10):
        h.record(0.0)  # fully hidden stalls land in the underflow bucket
    assert h.percentile(0.5) == 0.0
    h.record(1e6)  # beyond hi: overflow bucket, estimated by the max bound
    assert h.percentile(0.999) == pytest.approx(1e6)
    assert h.count == 11
    h.record(-1.0)  # negatives clamp to zero, never throw off the sum
    assert h.min == 0.0 and h.sum == pytest.approx(1e6)


def test_log_buckets_are_log_spaced():
    edges = log_buckets(1e-6, 100.0, per_decade=8)
    assert edges[0] == pytest.approx(1e-6)
    assert edges[-1] == pytest.approx(100.0)
    ratios = [b / a for a, b in zip(edges, edges[1:])]
    assert all(r == pytest.approx(10 ** (1 / 8)) for r in ratios)


def test_histogram_merge_pools_populations():
    a, b = Histogram(exact=True), Histogram(exact=True)
    for x in (0.001, 0.002):
        a.record(x)
    for x in (0.003, 0.004):
        b.record(x)
    a.merge_from(b)
    assert a.count == 4
    assert a.percentile(0.5) == pytest.approx(0.0025)


def test_histogram_self_metering_charges_the_overhead_ledger():
    meter = Meter()
    h = Histogram(exact=True, meter=meter)
    h.record(0.001)
    assert meter.events == 1 and meter.seconds > 0.0
    obs = Observability(tracing=True)
    obs.registry.histogram("x").record(0.5)
    obs.tracer.predicted([1], t=0.0)
    obs.tracer.drop_active(t=1.0)
    ledger = Overhead()
    obs.charge(ledger)
    assert ledger.obs_events >= 2
    assert ledger.obs_seconds > 0.0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_get_or_create_and_merged_percentiles():
    reg = Registry()
    assert reg.counter("hits", service=0) is reg.counter("hits", service=0)
    assert reg.counter("hits", service=0) is not reg.counter("hits", service=1)
    reg.histogram("stall_s", service=0).record(0.001)
    reg.histogram("stall_s", service=1).record(0.1)
    merged = reg.merged_histogram("stall_s")
    assert merged.count == 2
    assert reg.percentiles("missing") == [None, None, None]
    reg.register_source("store", lambda: {"app_loads": 3})
    snap = reg.snapshot()
    assert snap["sources"]["store"] == {"app_loads": 3}
    assert len(snap["histograms"]["stall_s"]) == 2
    reg.reset()
    assert reg.merged_histogram("stall_s").count == 0
    assert reg.meter.events == 0


# ---------------------------------------------------------------------------
# span lifecycle (unit level)
# ---------------------------------------------------------------------------


def test_tracer_full_lifecycle_and_invariants():
    tr = Tracer(session="t")
    tr.predicted([1, 2], origin="capre:m", t=0.0)
    bid = tr.new_batch()
    tr.dispatched([1, 2], service=0, batch_id=bid, t=1.0)
    tr.claimed([1, 2], service=0, t=2.0)
    tr.loaded([1], service=0, lane=0, queued_t=2.0, start_t=3.0, done_t=5.0)
    tr.loaded([2], service=0, lane=1, queued_t=2.0, start_t=3.0, done_t=5.0)
    tr.demand(1, service=0, needed_t=6.0, stall_s=0.0, full_load=False,
              disk_load_s=2.0, t=6.0)  # resident -> hit
    tr.evicted(2, t=7.0)  # never demanded -> evicted
    spans = tr.spans()
    assert check_span_invariants(spans) == []
    by_oid = {s.oid: s for s in spans}
    assert by_oid[1].outcome == "hit"
    assert by_oid[1].hidden_s == pytest.approx(2.0)
    assert by_oid[1].slot_wait_s == pytest.approx(1.0)
    assert by_oid[1].service_s == pytest.approx(2.0)
    assert by_oid[1].session == "t"
    assert by_oid[2].outcome == "evicted"
    assert tr.counts()["outcome_hit"] == 1


def test_tracer_partial_miss_suppressed_and_demand_shape():
    tr = Tracer()
    # partial: load lands after the need
    tr.predicted([1], t=0.0)
    tr.dispatched([1], 0, tr.new_batch(), t=0.0)
    tr.claimed([1], 0, t=0.0)
    tr.loaded([1], 0, 0, 0.0, 0.0, 10.0)
    tr.demand(1, 0, needed_t=4.0, stall_s=6.0, full_load=False,
              disk_load_s=10.0, t=10.0)
    # suppressed: deduped before any claim
    tr.predicted([2], t=0.0)
    tr.dispatched([2], 0, tr.new_batch(), t=0.0)
    tr.suppressed([2], 0, t=1.0)
    # unpredicted demand miss gets the symmetric span shape
    tr.demand(3, 0, needed_t=5.0, stall_s=10.0, full_load=True,
              disk_load_s=10.0, t=15.0)
    spans = {s.oid: s for s in tr.spans()}
    assert check_span_invariants(list(spans.values())) == []
    assert spans[1].outcome == "partial"
    assert spans[1].hidden_s == pytest.approx(4.0)  # 10 - 6 waited out
    assert spans[2].outcome == "suppressed"
    assert spans[3].outcome == "miss" and spans[3].kind == "demand"
    assert spans[3].load_done_t == pytest.approx(15.0)


def test_span_refuses_a_second_terminal_state():
    tr = Tracer()
    tr.predicted([1], t=0.0)
    span = tr.spans()[0]
    tr.dropped([1], t=1.0)
    with pytest.raises(SpanError):
        tr._finish(span, "hit", 2.0)


def test_repeat_prediction_of_a_live_span_counts_re_predicted():
    tr = Tracer()
    tr.predicted([1], t=0.0)
    tr.predicted([1], t=1.0)
    tr.dispatched([1], 0, tr.new_batch(), t=1.0)
    tr.claimed([1], 0, t=1.0)
    tr.suppressed([1], 0, t=2.0)  # claimed: not terminal, another re-predict
    assert tr.active_count() == 1
    span = tr.spans()[0]
    assert span.re_predicted == 2
    tr.drop_active(t=3.0)
    assert tr.spans()[0].outcome == "dropped"


# ---------------------------------------------------------------------------
# span lifecycle on the live store (wall clock)
# ---------------------------------------------------------------------------

# real (small) sleeps: with a zero-latency model the demand path wins every
# race against the prefetch pool and no span ever reaches "hit"
_WALL_LAT = LatencyModel(disk_load=300e-6, remote_hop=120e-6, write_back=900e-6,
                         think=100e-6, parallel_per_ds=1)


@pytest.fixture(scope="module")
def wall_bank():
    wl = _catalog()["bank"]
    client = POSClient(n_services=4, latency=_WALL_LAT)
    obs = Observability(tracing=True)
    client.store.attach_obs(obs)
    client.register(wl.build_app())
    root = wl.populate(client.store)
    with client.session(wl.name, mode="capre", parallel_workers=8,
                        session_label="bank-wall") as s:
        wl.run_once(s, root)
        assert s.drain(10.0)
        # snapshot while the session is live: its runtime/<label> source is
        # registered now and unregistered on close (the lifecycle the
        # multi-tenant registry fix enforces)
        live_snap = obs.snapshot()
    client.store.reset_runtime_state()  # terminates never-demanded residents
    return obs, client, root, wl, live_snap


def test_live_store_spans_all_reach_exactly_one_terminal_state(wall_bank):
    obs, client, root, wl, _live_snap = wall_bank
    spans = obs.tracer.spans()
    assert spans and obs.tracer.active_count() == 0
    assert check_span_invariants(spans) == []
    outcomes = {sp.outcome for sp in spans}
    assert "hit" in outcomes
    assert all(sp.session == "bank-wall" for sp in spans)
    # a second run WITHOUT an orderly drain: reset_runtime_state hard-drains
    # the runtime and the invariant must still hold
    with client.session(wl.name, mode="capre", parallel_workers=8,
                        session_label="bank-wall") as s:
        wl.run_once(s, root)
    client.store.reset_runtime_state()
    assert obs.tracer.active_count() == 0
    assert check_span_invariants(obs.tracer.spans()) == []


def test_live_store_demand_stall_histograms_and_sources(wall_bank):
    obs, _client, _root, _wl, live_snap = wall_bank
    # a live session exposes its runtime as a source...
    assert any(k.startswith("runtime/") for k in live_snap["sources"])
    snap = obs.snapshot()
    assert "store" in snap["sources"]
    # ...and close() unregisters it: no leaked source pinning a shut-down
    # PrefetchRuntime after the session ends
    assert not any(k.startswith("runtime/") for k in snap["sources"])
    merged = obs.registry.merged_histogram("demand_stall_s")
    assert merged is not None and merged.count > 0
    assert snap["self"]["events"] > 0  # instrumentation metered itself
    assert snap["spans"]["active"] == 0


# ---------------------------------------------------------------------------
# virtual clock: replay spans, parity, percentile columns
# ---------------------------------------------------------------------------


def _virtual_bank(tracer=None, calibration=None):
    wl = _catalog()["bank"]
    client, _root, traces = record_workload(wl, runs=2)
    reg = client.logic_module.registered[wl.name]
    predictor = make_pos_predictor("static-capre", config=SessionConfig(rop_depth=2))
    predictor.warm(traces[0].accesses)
    return replay(traces[-1], predictor, client.store, reg, dispatch="batch",
                  tracer=tracer, calibration=calibration)


FULL_CHAIN = ("predicted_t", "dispatched_t", "claimed_t", "queued_t",
              "load_start_t", "load_done_t", "outcome_t")


def test_replay_spans_hold_the_same_invariants():
    tr = Tracer()
    _res = _virtual_bank(tracer=tr)
    spans = tr.spans()
    assert spans and tr.active_count() == 0
    assert check_span_invariants(spans) == []
    assert any(sp.fields_set() == FULL_CHAIN for sp in spans)


def test_wall_and_virtual_spans_populate_identical_fields(wall_bank):
    obs, _c, _r, _w, _snap = wall_bank
    tr = Tracer()
    _virtual_bank(tracer=tr)

    def hit_shapes(spans):
        return {sp.fields_set() for sp in spans
                if sp.kind == "prefetch" and sp.outcome == "hit"
                and sp.load_done_t is not None}

    wall, virt = hit_shapes(obs.tracer.spans()), hit_shapes(tr.spans())
    # the full lifecycle shape exists on both clocks, and neither clock
    # produces a hit-span shape the other cannot
    assert FULL_CHAIN in wall and FULL_CHAIN in virt
    assert wall == virt


def test_replay_result_carries_gated_percentile_columns():
    tr = Tracer()
    res = _virtual_bank(tracer=tr, calibration=Calibration(app_scales={"bank": 0.5}))
    assert 0.0 <= res.stall_p50_s <= res.stall_p99_s <= res.stall_p999_s
    assert res.stall_p999_s > 0.0  # bank always pays at least the cold miss
    assert res.calib_scale == pytest.approx(0.5)
    assert res.calibrated_stall_s == pytest.approx(res.stall_seconds * 0.5)
    for col in ("stall_p50_s", "stall_p99_s", "stall_p999_s", "calib_scale",
                "calibrated_stall_s", "obs_seconds", "obs_events"):
        assert col in CSV_COLUMNS
    # instrumentation charged itself to the ledger
    assert res.overhead["obs_events"] > 0
    assert res.overhead["obs_seconds"] >= 0.0


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------


def test_chrome_trace_schema_and_phase_coverage(tmp_path):
    tr = Tracer()
    _virtual_bank(tracer=tr)
    spans = tr.spans()
    obj = chrome_trace(spans, clock="virtual")
    assert validate_chrome_trace(obj) == []
    json.dumps(obj)  # serializable end to end
    phases = full_lifecycle_phase_counts(obj)
    loaded = [s for s in spans if s.kind == "prefetch" and s.load_done_t is not None]
    assert loaded
    assert all(phases.get(s.oid, 0) >= 4 for s in loaded)
    path = tmp_path / "replay.trace.json"
    write_chrome_trace(str(path), spans, clock="virtual")
    with open(path) as f:
        round_tripped = json.load(f)
    assert validate_chrome_trace(round_tripped) == []
    # counter tracks made it out (disk occupancy and/or demand queue)
    assert any(ev["ph"] == "C" for ev in round_tripped["traceEvents"])


def test_validate_chrome_trace_rejects_malformed_events(tmp_path):
    assert validate_chrome_trace([]) != []  # not even a dict
    assert validate_chrome_trace({"events": []}) != []  # wrong key
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": -1.0, "pid": 0,
                            "tid": 0, "dur": -2.0}]}
    problems = validate_chrome_trace(bad)
    assert any("ts" in p for p in problems)
    assert any("dur" in p for p in problems)
    # an empty span list still writes a *valid* (empty) trace — the writer
    # only raises when validation reports schema problems
    trace = write_chrome_trace(str(tmp_path / "empty.json"), [], clock="virtual")
    assert trace["traceEvents"] == []


# ---------------------------------------------------------------------------
# compare_predict: percentile presence + p99 tail gate
# ---------------------------------------------------------------------------

_GATE_HEADER = (
    "app,workload,predictor,cache_capacity,policy,timely_coverage,"
    "stall_saved_pct,writes,write_hits,dirty_evictions,flushed_writes,"
    "protected_evictions,dispatch,batch_dispatches,dedup_suppressed,"
    "stall_p50_s,stall_p99_s,stall_p999_s,calib_scale,calibrated_stall_s,"
    "placement,replication,scenario,failovers,"
    "rfo_prefetches,truncated_hints,hint_priority_mean,ownership_upgrades,"
    "exec_delayed,write_quorum,readmissions,resync_lines,hedged_reads,"
    "hedge_wins,quorum_writes,quorum_acks,quorum_retries,quorum_failures\n"
)


def _gate_row(p99: float) -> str:
    return (f"bank,auditAll,static-capre,64,lru,0.99,98.9,0,0,0,0,,batch,4,2,"
            f"0.0,{p99},{p99},1.0,0.01\n")


def test_compare_predict_gates_percentile_columns_and_p99(tmp_path):
    from benchmarks.compare_predict import compare

    base = tmp_path / "baseline.csv"
    base.write_text(_GATE_HEADER + _gate_row(0.010))
    # within 10% relative headroom: ok
    ok = tmp_path / "ok.csv"
    ok.write_text(_GATE_HEADER + _gate_row(0.0108))
    assert compare(str(ok), str(base)) == []
    # tail regression beyond headroom: fail, naming the column
    slow = tmp_path / "slow.csv"
    slow.write_text(_GATE_HEADER + _gate_row(0.013))
    failures = compare(str(slow), str(base))
    assert any("stall_p99_s" in f for f in failures)
    # sub-floor tails never trip on jitter (absolute epsilon)
    tiny_base = tmp_path / "tiny_base.csv"
    tiny_base.write_text(_GATE_HEADER + _gate_row(0.0))
    tiny = tmp_path / "tiny.csv"
    tiny.write_text(_GATE_HEADER + _gate_row(0.0004))
    assert compare(str(tiny), str(tiny_base)) == []
    # a pre-observability header (no percentile columns) fails the gate
    old = tmp_path / "old.csv"
    old_header = _GATE_HEADER.replace(
        ",stall_p50_s,stall_p99_s,stall_p999_s,calib_scale,calibrated_stall_s", "")
    old.write_text(old_header
                   + "bank,auditAll,static-capre,64,lru,0.99,98.9,0,0,0,0,,batch,4,2\n")
    failures = compare(str(old), str(base))
    assert any("stall-percentile columns missing" in f for f in failures)


def test_committed_baseline_carries_percentile_columns():
    import csv

    with open("artifacts/predict/baseline.csv", newline="") as f:
        fields = csv.DictReader(f).fieldnames
    for col in ("stall_p50_s", "stall_p99_s", "stall_p999_s",
                "calib_scale", "calibrated_stall_s"):
        assert col in fields


# ---------------------------------------------------------------------------
# calibration loader (single source of truth)
# ---------------------------------------------------------------------------


def test_calibration_loader_parses_fitted_scales(tmp_path):
    path = tmp_path / "calibration.csv"
    path.write_text(
        "app,workload,predictor,scale_app,scale_global\n"
        "bank,auditAll,capre,0.25,0.70\n"
        "oo7,traverse,capre,0.73,0.70\n"
    )
    cal = load_calibration(str(path))
    assert cal.fitted
    assert cal.scale_for("bank") == pytest.approx(0.25)
    assert cal.scale_for("oo7") == pytest.approx(0.73)
    assert cal.scale_for("unknown") == pytest.approx(0.70)  # global fallback
    model = calibrated_model("bank", base=REPLAY, calibration=cal)
    assert model.disk_load == pytest.approx(REPLAY.disk_load * 0.25)
    assert model.parallel_per_ds == REPLAY.parallel_per_ds  # slots untouched
    # missing file: identity, never an error
    cal = load_calibration(str(tmp_path / "nope.csv"))
    assert not cal.fitted and cal.scale_for("bank") == 1.0
    # the committed artifact parses and fits every catalog app
    committed = load_calibration()
    assert committed.fitted and committed.scale_for("bank") > 0.0
    # the mutating bank traversal calibrates under its own key
    assert _calibration_app_key("bank", "setAllTransCustomers") == "bank_write"
    assert _calibration_app_key("bank", "auditAll") == "bank"


# ---------------------------------------------------------------------------
# WeightStreamer through the shared registry (dispatch A/B)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dispatch", ["batch", "per-oid"])
def test_weight_streamer_records_through_the_registry(dispatch):
    jnp = pytest.importorskip("jax.numpy")
    from repro.runtime.prefetch import HostParamStore, WeightStreamer

    params = {"a": jnp.ones((64,)), "b": jnp.ones((64,)), "c": jnp.ones((64,))}
    store = HostParamStore(params, bandwidth_gbps=100.0, base_latency_s=1e-5)
    reg = Registry()
    ws = WeightStreamer(store, plan=None, mode=None, workers=2,
                        dispatch=dispatch, registry=reg)
    try:
        ws.fetch_group(["a", "b"])
        ws.fetch_group(["a", "b"])  # in flight or cached: all suppressed
        assert ws.get("a").shape == (64,)
        assert ws.get("c").shape == (64,)  # pure demand fetch
    finally:
        ws.close()
    assert ws.metrics.dedup_suppressed >= 2
    assert ws.metrics.batch_dispatches >= (2 if dispatch == "per-oid" else 1)
    snap = reg.snapshot()
    assert snap["sources"]["stream"]["fetches"] == ws.metrics.fetches
    hist = reg.merged_histogram("stream_stall_s")
    assert hist is not None and hist.count >= 2  # every get recorded
