"""Bounded-cache (LRU) behavior: the thrashing regime the paper's unbounded
caches avoid — useless ROP prefetches evict objects the application still
needs, while CAPre's exact hints do not."""

from repro.apps.wordcount import build_wordcount_app, populate_wordcount
from repro.pos.client import POSClient
from repro.pos.latency import ZERO
from repro.pos.store import ObjectStore


def test_lru_evicts_least_recently_used():
    store = ObjectStore(n_services=1, latency=ZERO, cache_capacity=3)
    ds = store.services[0]
    oids = [store.put("X", {"i": i}) for i in range(5)]
    for o in oids[:3]:
        ds.load_into_memory(o)
    ds.load_into_memory(oids[0])  # bump 0 to most-recent
    ds.load_into_memory(oids[3])  # evicts 1
    assert ds.is_cached(oids[0])
    assert not ds.is_cached(oids[1])
    assert ds.is_cached(oids[2]) and ds.is_cached(oids[3])
    assert ds.evictions == 1


def test_unbounded_cache_never_evicts():
    store = ObjectStore(n_services=1, latency=ZERO, cache_capacity=0)
    ds = store.services[0]
    for i in range(100):
        ds.load_into_memory(store.put("X", {"i": i}))
    assert ds.evictions == 0
    assert len(ds.cache) == 100


def test_bounded_cache_increases_misses_under_rop_but_capre_recall_survives():
    """With a tight cache, the exact-hint prefetcher still front-runs the
    app (prefetch->use distance is short), while repeated cold misses show
    up without prefetching."""
    from repro.pos.latency import LatencyModel

    lat = LatencyModel(disk_load=250e-6, remote_hop=0.0, write_back=0.0, think=120e-6)
    results = {}
    for mode in (None, "capre"):
        client = POSClient(n_services=4)
        # rebuild with bounded caches and real latencies (the prefetcher
        # needs lead time to demonstrate hits on a single-visit workload)
        client.store = ObjectStore(n_services=4, latency=lat, cache_capacity=64)
        client.register(build_wordcount_app())
        root = populate_wordcount(client.store, chunks_per_text=16, words_per_chunk=8)
        with client.session("wordcount", mode=mode, parallel_workers=16) as s:
            s.execute(root, "run")
            s.drain(10.0)
        results[mode] = client.store.metrics.snapshot()
    # under CAPre most app-path accesses are hits even with a bounded cache
    assert results["capre"]["app_cache_hits"] > results[None]["app_cache_hits"]
    assert results["capre"]["app_cache_misses"] < results[None]["app_cache_misses"]
