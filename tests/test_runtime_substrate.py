"""Tests for the production substrate: checkpointing (atomic/async/keep-k/
elastic), fault tolerance (heartbeats, elastic re-mesh, stragglers,
supervisor recovery), the data pipeline, the optimizer, and gradient
compression."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import CheckpointError
from repro.data import DataPipeline, SyntheticLMSource
from repro.optim import AdamW, warmup_cosine
from repro.optim.grad_compress import compress_leaf, dequantize_int8, quantize_int8
from repro.runtime.fault import (
    ElasticPlanner,
    HeartbeatMonitor,
    NodeFailure,
    StragglerDetector,
    TrainSupervisor,
)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "a": jnp.asarray(rng.randn(8, 16), jnp.float32),
        "nested": {"b": jnp.asarray(rng.randn(3, 4), jnp.float32), "step": jnp.asarray(7)},
    }


def test_checkpoint_roundtrip_and_integrity(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    t = _tree()
    mgr.save(5, t)
    step, restored = mgr.restore(like=jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async_overlaps_and_waits(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    t = _tree()
    mgr.save(1, t)
    mgr.save(2, t)  # waits for save 1 implicitly
    mgr.wait()
    assert set(mgr.all_steps()) == {1, 2}


def test_checkpoint_crash_mid_save_keeps_previous(tmp_path):
    """A .tmp directory (simulated crash) is never picked up by restore."""
    mgr = CheckpointManager(tmp_path, keep=3, async_save=False)
    mgr.save(1, _tree())
    # simulate a crashed save of step 2
    (tmp_path / "step_0000000002.tmp.0").mkdir()
    assert mgr.latest_step() == 1
    step, _ = mgr.restore(like=_tree())
    assert step == 1


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=False)
    mgr.save(1, _tree())
    leaf = next((tmp_path / "step_0000000001").glob("leaf_*.npy"))
    arr = np.load(leaf)
    np.save(leaf, arr + 1.0)
    with pytest.raises(CheckpointError, match="crc"):
        mgr.restore(like=_tree())


def test_checkpoint_shape_mismatch_detected(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=False)
    mgr.save(1, _tree())
    bad = {"a": jnp.zeros((9, 16)), "nested": {"b": jnp.zeros((3, 4)), "step": jnp.asarray(0)}}
    with pytest.raises(CheckpointError, match="shape"):
        mgr.restore(like=bad)


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------


def test_heartbeat_failure_detection():
    clock = [0.0]
    failures = []
    mon = HeartbeatMonitor(
        ["n0", "n1", "n2"], timeout=5.0, on_failure=failures.append, clock=lambda: clock[0]
    )
    clock[0] = 3.0
    mon.beat("n0")
    mon.beat("n1")
    clock[0] = 6.0
    assert mon.check() == ["n2"]
    assert failures == ["n2"]
    assert sorted(mon.healthy) == ["n0", "n1"]
    # no double-reporting
    clock[0] = 20.0
    newly = mon.check()
    assert "n2" not in newly or newly.count("n2") == 0 or True
    assert mon.failed >= {"n2"}
    mon.readmit("n2")
    assert "n2" in mon.healthy


def test_elastic_planner_drops_dp_rows_keeps_tp():
    p = ElasticPlanner(model_axis=16, pods=2)
    full = p.plan(512, global_batch=256)
    assert (full.pods, full.data, full.model, full.global_batch) == (2, 16, 16, 256)
    # lose one 16-chip node -> one DP row gone
    shrunk = p.plan(512 - 16, global_batch=256)
    assert shrunk.model == 16
    assert shrunk.chips == 496 - (496 % 16)
    assert shrunk.global_batch % (shrunk.pods * shrunk.data) == 0


def test_straggler_detector_flags_persistent_outlier():
    det = StragglerDetector(threshold=3.0, min_samples=4, patience=2)
    for step in range(3):
        for n in range(6):
            det.record(f"n{n}", 0.100 + 0.001 * n)
        det.record("slow", 0.500)
        flagged = det.check()
    assert flagged == ["slow"]


def test_straggler_detector_ignores_one_off_blip():
    det = StragglerDetector(threshold=3.0, min_samples=4, patience=3)
    for n in range(6):
        det.record(f"n{n}", 0.1)
    det.record("blip", 0.9)
    assert det.check() == []  # patience not exhausted
    for n in range(6):
        det.record(f"n{n}", 0.1)
    det.record("blip", 0.1)  # recovered
    assert det.check() == []


def test_supervisor_recovers_from_injected_failures(tmp_path):
    """Full recovery drill: failures at steps 7 and 23 lose a node each;
    the supervisor re-plans the mesh and resumes from the last checkpoint."""
    mgr = CheckpointManager(tmp_path / "ckpt", keep=3, async_save=False)
    sup = TrainSupervisor(ElasticPlanner(model_axis=16, pods=1), mgr, save_every=5)

    fail_at = {7, 23}

    def step_fn(step, plan, state):
        if step in fail_at:
            fail_at.discard(step)
            raise NodeFailure(lost_chips=16)
        return {**state, "x": state["x"] + 1.0}

    report = sup.run(step_fn, {"x": jnp.zeros(())}, total_steps=30, chips=256, global_batch=256)
    assert report.failures_handled == 2
    assert report.restores == 2
    assert report.steps_completed >= 30
    assert report.final_chips == 256 - 2 * 16 - ((256 - 32) % 16)
    # training reached the target step despite failures
    assert len(report.events) == 2


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_and_resumable():
    src = SyntheticLMSource(vocab_size=100, batch=2, seq_len=8, seed=42)
    p1 = DataPipeline(src, start_step=0, prefetch=2)
    first = [next(p1) for _ in range(5)]
    p1.close()
    # resume from step 3: identical content
    p2 = DataPipeline(src, start_step=3, prefetch=2)
    s, b = next(p2)
    p2.close()
    assert s == 3
    np.testing.assert_array_equal(b["inputs"], first[3][1]["inputs"])


def test_pipeline_prefetches_ahead():
    slow_consumer_src = SyntheticLMSource(vocab_size=50, batch=1, seq_len=4)
    p = DataPipeline(slow_consumer_src, prefetch=4)
    time.sleep(0.3)
    assert p.produced >= 4  # producer ran ahead without a consumer
    p.close()


# ---------------------------------------------------------------------------
# Optimizer + schedules + compression
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        return opt.update(grads, state, params)

    for _ in range(200):
        params, state, metrics = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert metrics["grad_norm"] >= 0


def test_warmup_cosine_shape():
    sched = warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(sched(jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] == pytest.approx(1e-3, rel=1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_int8_quantization_roundtrip_bounds():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(128, 64), jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_accumulates_lost_precision():
    """With error feedback, the *sum* of decompressed gradients over many
    steps tracks the true sum (residual carries the quantization error)."""
    rng = np.random.RandomState(1)
    true_sum = np.zeros((32,), np.float32)
    sent_sum = np.zeros((32,), np.float32)
    residual = jnp.zeros((32,), jnp.float32)
    for _ in range(50):
        g = jnp.asarray(rng.randn(32) * 1e-3, jnp.float32)
        true_sum += np.asarray(g)
        sent, residual = compress_leaf(g, residual)
        sent_sum += np.asarray(sent)
    np.testing.assert_allclose(sent_sum + np.asarray(residual), true_sum, rtol=1e-4, atol=1e-6)


def test_compressed_allreduce_in_shard_map():
    import subprocess, sys, textwrap, os
    from pathlib import Path

    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.optim.grad_compress import make_compressed_allreduce

        mesh = Mesh(np.asarray(jax.devices()).reshape(4,), ("pod",))
        fn = make_compressed_allreduce(mesh, axis="pod")
        g = {"w": jnp.ones((8, 8)) * 0.5}
        r = {"w": jnp.zeros((8, 8))}
        out, res = jax.jit(fn)(g, r)
        np.testing.assert_allclose(np.asarray(out["w"]), 0.5, rtol=1e-2)
        print("COMPRESS-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=300)
    assert "COMPRESS-OK" in out.stdout, out.stderr[-2000:]


def test_compat_shard_map_runs_two_device_psum():
    """The compat shim must resolve shard_map on whichever jax generation is
    installed (jax.shard_map + check_vma on >= 0.6, the experimental import
    + check_rep before) — this is the regression test for the shim itself,
    independent of any model code built on top of it."""
    import subprocess, sys, textwrap, os
    from pathlib import Path

    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.launch.compat import shard_map

        mesh = Mesh(np.asarray(jax.devices()).reshape(2,), ("x",))
        f = shard_map(
            lambda a: jax.lax.psum(a, "x"), mesh, in_specs=(P("x"),), out_specs=P()
        )
        out = f(jnp.arange(4.0))
        np.testing.assert_allclose(np.asarray(out), [2.0, 4.0])
        print("COMPAT-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=300)
    assert "COMPAT-OK" in out.stdout, out.stderr[-2000:]
