"""Edge cases of the augmented-type-graph analysis and the static hint
optimizer (ISSUE 8): ``_covers_unconditional`` over nested conditionals,
loop-taint interaction with grafted callee subtrees, recursion-cut call
recording, all-callers dedup under dynamic dispatch, the opt.py passes
(write-set projection, partial-traversal truncation, cost/priority model)
and the capre-lint verifier."""

import pytest

from repro.core import lang
from repro.core.hints import analyze_application, generate
from repro.core.lang import (
    Application,
    Break,
    Call,
    ClassDef,
    Compute,
    COLLECTION,
    ExprStmt,
    FieldSpec,
    ForEach,
    Get,
    If,
    MethodDef,
    Return,
    SetField,
    This,
    Var,
    fields_of,
)
from repro.core.lint import (
    DEFAULT_APPS,
    analyze,
    diff_golden,
    golden_payload,
    lint_report,
)
from repro.core.opt import (
    DEFAULT_COLLECTION_FANOUT,
    DEFAULT_PREFIX_BOUND,
    hint_cost,
    hint_priority,
)
from repro.core.type_graph import CAPreAnalysis, _covers_unconditional


def _noop(name="noop"):
    return ExprStmt(Compute(lambda: None, (), name))


def _cond(name="c"):
    return Compute(lambda: True, (), name)


# ---------------------------------------------------------------------------
# _covers_unconditional: nested conditionals
# ---------------------------------------------------------------------------


def test_covers_unconditional_trivial_cases():
    assert _covers_unconditional({()})
    assert not _covers_unconditional(set())
    # one arm of a 2-way conditional does not cover
    assert not _covers_unconditional({((1, 0, 2),)})
    # both arms do
    assert _covers_unconditional({((1, 0, 2),), ((1, 1, 2),)})


def test_covers_unconditional_nested_reduction():
    """An occurrence in the else arm plus occurrences in BOTH nested arms of
    the then branch reduces bottom-up to full coverage."""
    paths = {
        ((1, 1, 2),),                 # else arm of the outer conditional
        ((1, 0, 2), (2, 0, 2)),       # then arm, nested then
        ((1, 0, 2), (2, 1, 2)),       # then arm, nested else
    }
    assert _covers_unconditional(paths)
    # drop one nested arm: the outer then is only partially covered
    assert not _covers_unconditional(paths - {((1, 0, 2), (2, 1, 2))})


def test_covers_unconditional_incomplete_nested():
    assert not _covers_unconditional({
        ((1, 0, 2), (2, 0, 2)),
        ((1, 1, 2), (3, 0, 2)),  # else arm only via one nested branch
    })


def test_nested_conditional_branch_dependence_end_to_end():
    """A navigation occurring in every leaf of a nested conditional is NOT
    branch-dependent; one missing leaf makes it so."""
    leaf = ClassDef("Leaf", fields_of(FieldSpec("x")))
    node = ClassDef("Node", fields_of(FieldSpec("a", target="Leaf"),
                                      FieldSpec("b", target="Leaf")))
    node.add_method(MethodDef("m", params=(), body=[
        If(cond=_cond("outer"),
           then=[If(cond=_cond("inner"),
                    then=[ExprStmt(Get(This(), "a"))],
                    els=[ExprStmt(Get(This(), "a"))])],
           els=[ExprStmt(Get(This(), "a")),
                ExprStmt(Get(This(), "b"))]),
    ]))
    app = Application(name="nested", classes={c.name: c for c in (leaf, node)})
    g = CAPreAnalysis(app).analyze_all()["Node.m"]
    children = g.this_root.children
    assert not children["a"].branch_dependent  # present in every leaf
    assert children["b"].branch_dependent      # else arm only


# ---------------------------------------------------------------------------
# loop taint x grafted callee subtrees
# ---------------------------------------------------------------------------


def _graft_app(caller_body):
    item = ClassDef("Item", fields_of(FieldSpec("detail", target="Detail"),
                                      FieldSpec("amount")))
    item.add_method(MethodDef("touch", params=(), ret_type=None, body=[
        ExprStmt(Get(Get(This(), "detail"), "amount")),
    ]))
    detail = ClassDef("Detail", fields_of(FieldSpec("amount")))
    box = ClassDef("Box", fields_of(
        FieldSpec("items", target="Item", card=COLLECTION)))
    box.add_method(MethodDef("scan", params=(), body=caller_body))
    return Application(name="graft", classes={c.name: c for c in (item, detail, box)})


def test_grafted_subtree_inherits_loop_taint():
    """A callee grafted inside an early-exit loop lands with every grafted
    navigation tainted: the loop may stop before reaching any element."""
    app = _graft_app([
        ForEach("it", This(), "items", [
            ExprStmt(Call(Var("it"), "touch")),
            Break(),
        ]),
    ])
    g = CAPreAnalysis(app).analyze_all()["Box.scan"]
    items = g.this_root.children["items"]
    detail = items.children["detail"]
    assert all(tainted for _bp, tainted in items.occurrences)
    assert all(tainted for _bp, tainted in detail.occurrences)
    assert items.branch_dependent and detail.branch_dependent


def test_grafted_subtree_clean_in_untainted_loop():
    """The same graft in a plain full traversal stays clean — taint comes
    from the loop, not from grafting itself."""
    app = _graft_app([
        ForEach("it", This(), "items", [
            ExprStmt(Call(Var("it"), "touch")),
        ]),
    ])
    g = CAPreAnalysis(app).analyze_all()["Box.scan"]
    items = g.this_root.children["items"]
    detail = items.children["detail"]
    assert any(not tainted for _bp, tainted in detail.occurrences)
    assert not detail.branch_dependent


def test_grafted_callee_write_set_propagates_conditionality():
    """Interprocedural write-set propagation collapses the callee's own
    branch structure into the taint bit: an unconditional callee write
    arrives clean, a conditional one arrives tainted."""
    item = ClassDef("Item", fields_of(FieldSpec("amount"), FieldSpec("flag")))
    item.add_method(MethodDef("always", params=(), body=[
        SetField(This(), "amount", Compute(lambda: 1, (), "one")),
    ]))
    item.add_method(MethodDef("sometimes", params=(), body=[
        If(cond=_cond(), then=[SetField(This(), "flag", Compute(lambda: 1, (), "one"))]),
    ]))
    box = ClassDef("Box", fields_of(
        FieldSpec("items", target="Item", card=COLLECTION)))
    box.add_method(MethodDef("creditEach", params=(), body=[
        ForEach("it", This(), "items", [ExprStmt(Call(Var("it"), "always"))]),
    ]))
    box.add_method(MethodDef("flagEach", params=(), body=[
        ForEach("it", This(), "items", [ExprStmt(Call(Var("it"), "sometimes"))]),
    ]))
    app = Application(name="wr", classes={c.name: c for c in (item, box)})
    graphs = CAPreAnalysis(app).analyze_all()
    credit = graphs["Box.creditEach"].this_root.children["items"]
    assert credit.written
    assert any(not t for _bp, t in credit.write_occurrences)
    flag = graphs["Box.flagEach"].this_root.children["items"]
    assert flag.written  # conditional writes still mark the update site
    assert all(t for _bp, t in flag.write_occurrences)


# ---------------------------------------------------------------------------
# recursion cut: call recording + hints kept at every level
# ---------------------------------------------------------------------------


def test_recursion_cut_records_ungrafted_call_site():
    node = ClassDef("Tree", fields_of(FieldSpec("left", target="Tree"),
                                      FieldSpec("val")))
    node.add_method(MethodDef("walk", params=(), body=[
        ExprStmt(Get(Get(This(), "left"), "val")),
        ExprStmt(Call(Get(This(), "left"), "walk")),
    ]))
    app = Application(name="rec", classes={"Tree": node})
    analysis = CAPreAnalysis(app)
    report = generate(analysis)
    sites = analysis.call_sites["Tree.walk"]
    assert sites and all(s.reason == "recursion" and not s.grafted for s in sites)
    # an ungrafted caller cannot cover: the recursive method KEEPS its hint
    # and re-schedules prefetching at every level (the rolling frontier)
    assert report.hints_str("Tree.walk") == {"left"}


# ---------------------------------------------------------------------------
# all-callers dedup under dynamic dispatch
# ---------------------------------------------------------------------------


def _dispatch_app(overridden: bool) -> Application:
    """A caller invoking Base.work on every element; when ``overridden`` a
    subtype overrides work, so the call must not be inlined."""
    part = ClassDef("Part", fields_of(FieldSpec("name")))
    base = ClassDef("Base", fields_of(FieldSpec("part", target="Part")))
    base.add_method(MethodDef("work", params=(), body=[
        ExprStmt(Get(Get(This(), "part"), "name")),
    ]))
    classes = [part, base]
    if overridden:
        sub = ClassDef("Sub", supertype="Base")
        sub.add_method(MethodDef("work", params=(), body=[_noop()]))
        classes.append(sub)
    owner = ClassDef("Owner", fields_of(
        FieldSpec("bases", target="Base", card=COLLECTION)))
    owner.add_method(MethodDef("runAll", params=(), body=[
        ForEach("b", This(), "bases", [ExprStmt(Call(Var("b"), "work"))]),
    ]))
    classes.append(owner)
    return Application(name="dyn", classes={c.name: c for c in classes})


def test_all_callers_dedup_with_monomorphic_callee():
    """No override: the callee grafts into its only caller, whose own hint
    covers it — the callee's hint is deduplicated away."""
    analysis = CAPreAnalysis(_dispatch_app(overridden=False))
    report = generate(analysis)
    assert report.full_hints_str("Base.work") == {"part"}
    assert report.hints_str("Base.work") == set()
    assert report.hints_str("Owner.runAll") == {"bases[].part"}
    sites = analysis.call_sites["Base.work"]
    assert all(s.grafted for s in sites)


def test_all_callers_dedup_skipped_under_dynamic_dispatch():
    """With an override, the call site is never inlined (section 4.4): the
    caller cannot cover the callee's hints, so Base.work keeps them and the
    caller's graph stops at the collection step."""
    analysis = CAPreAnalysis(_dispatch_app(overridden=True))
    report = generate(analysis)
    assert report.hints_str("Base.work") == {"part"}
    assert report.hints_str("Owner.runAll") == {"bases[]"}
    sites = analysis.call_sites["Base.work"]
    assert all(not s.grafted and s.reason == "overridden" for s in sites)


# ---------------------------------------------------------------------------
# optimizer passes (core.opt)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bank_report():
    from repro.apps.bank import build_bank_app

    return analyze_application(build_bank_app())


def test_opt_rfo_projection_on_bank(bank_report):
    """Pass 1: creditAll writes every transaction unconditionally;
    setAllTransCustomers updates accounts through the grafted setter."""
    by_method = {
        key: {str(h): h for h in hints}
        for key, hints in bank_report.hints.items()
    }
    credit = by_method["BankManagement.creditAll"]["transactions[]"]
    assert credit.rfo and credit.rfo_depths == (0,)
    setter = by_method["BankManagement.setAllTransCustomers"][
        "transactions[].account.cust.company"]
    assert setter.rfo_depths == (1,)  # the account is the update site
    audit = by_method["BankManagement.auditAll"][
        "transactions[].account.cust.company"]
    assert not audit.rfo  # read-only traversal: no ownership needed


def test_opt_truncation_on_early_exit_scan(bank_report):
    """Pass 2: findLargeTransaction's break makes every occurrence of the
    transactions[] step loop-tainted -> static prefix bound."""
    hints = {str(h): h for h in
             bank_report.hints["BankManagement.findLargeTransaction"]}
    h = hints["transactions[].account.cust"]
    assert h.truncated
    assert h.trunc_step == 0
    assert h.prefix_bound == DEFAULT_PREFIX_BOUND
    # the full-traversal companions are NOT truncated
    audit = {str(h): h for h in bank_report.hints["BankManagement.auditAll"]}
    assert all(not h.truncated for h in audit.values())


def test_opt_cost_and_priority_model():
    single = (("a", lang.SINGLE), ("b", lang.SINGLE))
    assert hint_cost(single) == 2.0
    coll = (("xs", lang.COLLECTION),)
    assert hint_cost(coll) == DEFAULT_COLLECTION_FANOUT
    nested = (("xs", lang.COLLECTION), ("ys", lang.COLLECTION))
    assert hint_cost(nested) == (DEFAULT_COLLECTION_FANOUT
                                 + DEFAULT_COLLECTION_FANOUT ** 2)
    # truncation caps the frontier at the trunc step
    assert hint_cost(coll, prefix_bound=4, trunc_step=0) == 4.0
    # priority: monotone decreasing in cost, bounded in (0, 1]
    costs = [1.0, 2.0, 16.0, 272.0]
    prios = [hint_priority(c) for c in costs]
    assert prios == sorted(prios, reverse=True)
    assert all(0.0 < p <= 1.0 for p in prios)


def test_opt_annotations_do_not_change_hint_identity(bank_report):
    """The optimizer decorates hints; eq/hash/dedup stay steps-only."""
    from dataclasses import replace

    h = bank_report.hints["BankManagement.creditAll"][0]
    plain = replace(h, rfo_depths=(), prefix_bound=None, trunc_step=None,
                    priority=0.0)
    assert plain == h and hash(plain) == hash(h)


# ---------------------------------------------------------------------------
# capre-lint (core.lint): verifier + golden drift
# ---------------------------------------------------------------------------


def test_lint_clean_on_all_catalog_apps():
    for name in DEFAULT_APPS:
        app, analysis, report = analyze(name)
        assert lint_report(app, analysis, report) == [], name


def test_lint_flags_corrupted_annotations():
    from dataclasses import replace

    app, analysis, report = analyze("bank")
    key = "BankManagement.auditAll"
    h = report.hints[key][0]
    report.hints[key] = (
        replace(h, rfo_depths=(99,), trunc_step=1, prefix_bound=None,
                priority=7.0),
    ) + report.hints[key][1:]
    kinds = {f.kind for f in lint_report(app, analysis, report)}
    assert "bounds" in kinds


def test_lint_flags_schema_drift():
    app, analysis, report = analyze("bank")
    key = "BankManagement.auditAll"
    from repro.core.hints import Hint

    report.hints[key] = report.hints[key] + (
        Hint((("no_such_field", lang.SINGLE),), priority=0.5),
    )
    findings = lint_report(app, analysis, report)
    assert any(f.kind == "schema" and "no_such_field" in f.message
               for f in findings)


def test_golden_diff_detects_hint_and_annotation_drift():
    reports = {name: analyze(name)[2] for name in ("bank", "wordcount")}
    golden = golden_payload(reports)
    assert diff_golden(golden, golden_payload(reports)) == []
    # annotation drift
    mutated = golden_payload(reports)
    rec = mutated["apps"]["bank"]["methods"]["BankManagement.creditAll"][0]
    rec["priority"] = 0.9999
    drift = diff_golden(golden, mutated)
    assert drift and any("annotations changed" in d for d in drift)
    # structural drift
    mutated2 = golden_payload(reports)
    mutated2["apps"]["bank"]["methods"].pop("BankManagement.creditAll")
    drift2 = diff_golden(golden, mutated2)
    assert any("disappeared" in d for d in drift2)


def test_committed_golden_matches_current_analysis():
    """The in-repo golden must track the analysis — the same gate CI runs."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..",
                        "artifacts", "analysis", "hints.json")
    with open(path) as fh:
        golden = json.load(fh)
    current = golden_payload({name: analyze(name)[2] for name in DEFAULT_APPS})
    assert diff_golden(golden, current) == []
