"""Distribution tests that need multiple devices run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count (per the dry-run rule:
never set it globally)."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_moe_ep_matches_dense():
    """The shard_map expert-parallel MoE path computes the same function as
    the single-device dense path."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models.moe import moe_apply_dense, moe_apply_ep
        from repro.models.model import Model

        cfg = get_smoke_config("qwen3_moe_30b_a3b").replace(moe_chunk=16)
        model = Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"]["mlp"])
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)

        dense = moe_apply_dense(x, lp, cfg, jnp.float32)

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        ep = jax.jit(lambda x, lp: moe_apply_ep(x, lp, cfg, jnp.float32, mesh, ("data",), "model"))(x, lp)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(ep), rtol=2e-5, atol=2e-5)
        print("EP-OK")
    """)
    assert "EP-OK" in _run_subprocess(code)


def test_moe_scatter_matches_einsum_dispatch():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.moe import moe_apply_dense
        from repro.models.model import Model

        cfg = get_smoke_config("granite_moe_1b_a400m").replace(moe_chunk=32, capacity_factor=4.0)
        model = Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"]["mlp"])
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
        y1 = moe_apply_dense(x, lp, cfg, jnp.float32)
        y2 = moe_apply_dense(x, lp, cfg.replace(moe_dispatch="scatter"), jnp.float32)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5, atol=2e-5)
        print("SCATTER-OK")
    """)
    assert "SCATTER-OK" in _run_subprocess(code, devices=1)


def test_smoke_train_step_sharded_end_to_end():
    """A tiny dense model trains under a (2, 4) mesh with the production
    sharding rules; loss decreases and matches the unsharded loss."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig
        from repro.models.common import activate_sharding
        from repro.models.model import Model
        from repro.launch.shardings import logical_rules, batch_pspecs, named
        from repro.launch.steps import make_train_step, concrete_batch

        cfg = get_smoke_config("chatglm3_6b")
        shape = ShapeConfig("t", "train", 16, 8)
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        model, opt, step = make_train_step(cfg, mesh)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        batch = concrete_batch(cfg, 8, 16)

        # unsharded reference loss
        ref_loss = float(model.loss_fn(params, batch))

        rules = logical_rules(cfg, shape, mesh)
        psh = named(mesh, model.param_pspecs(rules))
        params_s = jax.device_put(params, psh)
        opt_s = jax.device_put(opt_state, {"mu": psh, "nu": psh, "step": NamedSharding(mesh, P())})
        batch_s = jax.device_put(batch, named(mesh, batch_pspecs(cfg, shape, mesh)))
        with activate_sharding(mesh, rules):
            jstep = jax.jit(step)
            losses = []
            for i in range(4):
                params_s, opt_s, m = jstep(params_s, opt_s, batch_s)
                losses.append(float(m["loss"]))
        assert abs(losses[0] - ref_loss) < 1e-2, (losses[0], ref_loss)
        assert losses[-1] < losses[0], losses
        print("TRAIN-OK", losses[0], losses[-1])
    """)
    assert "TRAIN-OK" in _run_subprocess(code)


def test_hlo_parser_finds_collectives():
    """The HLO collective parser finds the gradient all-reduce of a sharded
    matmul step and multiplies while bodies by their trip count."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.launch.hlo_parse import collective_bytes

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        xs = NamedSharding(mesh, P("data", None))
        ws = NamedSharding(mesh, P(None, "model"))

        def step(x, ws_stack):
            def body(c, w):
                c = c @ w
                return jnp.sum(c) * jnp.ones_like(c), None
            y, _ = jax.lax.scan(body, x, ws_stack)   # sum -> all-reduce inside scan
            return jnp.sum(y)

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
        compiled = jax.jit(step, in_shardings=(xs, NamedSharding(mesh, P(None, None, "model")))).lower(x, w).compile()
        res = collective_bytes(compiled.as_text())
        assert res["bytes_per_device"] > 0, res
        total = sum(res["counts"].values())
        assert total >= 5, res  # scan-body collective counted 5 times
        print("HLO-OK", res["counts"])
    """)
    assert "HLO-OK" in _run_subprocess(code)
