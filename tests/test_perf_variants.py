"""Correctness of the §Perf hillclimb variants: each optimization must
compute the same function as its baseline (within quantization tolerance
where lossy by design)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import Model

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_fp8_kv_cache_decode_close_to_bf16():
    cfg = get_smoke_config("chatglm3_6b")
    model_ref = Model(cfg)
    model_fp8 = Model(cfg.replace(kv_cache_dtype="float8_e4m3fn"))
    params = model_ref.init_params(jax.random.PRNGKey(0))
    batch = {"inputs": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)}
    l_ref, c_ref = jax.jit(lambda p, b: model_ref.prefill(p, b))(params, batch)
    l_fp8, c_fp8 = jax.jit(lambda p, b: model_fp8.prefill(p, b))(params, batch)
    assert c_fp8["k"].dtype == jnp.float8_e4m3fn
    tok = jnp.argmax(l_ref, -1).astype(jnp.int32)
    d_ref, _ = jax.jit(lambda p, c, t: model_ref.decode_step(p, c, t, 16))(params, c_ref, tok)
    d_fp8, _ = jax.jit(lambda p, c, t: model_fp8.decode_step(p, c, t, 16))(params, c_fp8, tok)
    # prefill logits identical (cache dtype unused until decode)
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_fp8), rtol=1e-5, atol=1e-5)
    # decode: top-1 agreement + bounded drift (fp8 is lossy by design)
    assert np.mean(
        np.argmax(np.asarray(d_ref), -1) == np.argmax(np.asarray(d_fp8), -1)
    ) >= 0.5
    assert np.isfinite(np.asarray(d_fp8)).all()


def test_bf16_params_train_step_close():
    cfg = get_smoke_config("yi_34b")
    m32 = Model(cfg)
    m16 = Model(cfg.replace(param_dtype="bfloat16"))
    p32 = m32.init_params(jax.random.PRNGKey(0))
    p16 = jax.tree.map(lambda a: a.astype(jnp.bfloat16), p32)
    batch = {
        "inputs": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size),
    }
    l32 = float(m32.loss_fn(p32, batch))
    l16 = float(m16.loss_fn(p16, batch))
    assert abs(l32 - l16) / l32 < 0.02, (l32, l16)


def test_sequence_parallel_loss_matches_unsharded():
    """SP must be a pure re-layout: same loss as the unsharded model."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig
        from repro.models.common import activate_sharding
        from repro.models.model import Model
        from repro.launch.shardings import logical_rules, batch_pspecs, named
        from repro.launch.steps import concrete_batch

        cfg = get_smoke_config("yi_34b").replace(sequence_parallel=True)
        shape = ShapeConfig("t", "train", 16, 4)
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        model = Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, 4, 16)
        ref = float(model.loss_fn(params, batch))
        rules = logical_rules(cfg, shape, mesh)
        assert rules["seq"] == "model", rules
        params_s = jax.device_put(params, named(mesh, model.param_pspecs(rules)))
        batch_s = jax.device_put(batch, named(mesh, batch_pspecs(cfg, shape, mesh)))
        with activate_sharding(mesh, rules):
            got = float(jax.jit(lambda p, b: model.loss_fn(p, b))(params_s, batch_s))
        assert abs(got - ref) < 5e-3, (got, ref)
        print("SP-OK", got, ref)
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600)
    assert "SP-OK" in out.stdout, out.stderr[-3000:]


def test_scatter_dispatch_grad_flows():
    """The scatter dispatch must be differentiable (training variant)."""
    cfg = get_smoke_config("qwen3_moe_30b_a3b").replace(moe_dispatch="scatter", moe_chunk=16)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {
        "inputs": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size),
    }
    loss, grads = jax.jit(jax.value_and_grad(lambda p: model.loss_fn(p, batch)))(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32)**2) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
    expert_g = grads["layers"]["mlp"]["we_gate"]
    assert float(jnp.abs(expert_g).max()) > 0  # experts actually receive grads
