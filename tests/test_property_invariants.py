"""Hypothesis property tests on the system's invariants.

CAPre core (over randomly generated applications):
  * the analysis always terminates and never crashes (recursion/cycles/
    overrides included);
  * every generated hint is a valid navigation path through the
    application type graph G_T (schema soundness);
  * conservative (exclude) hints reach only objects the include policy also
    reaches;
  * caller-deduplicated hints are a subset of the full hints.

Sharding rules (over every assigned architecture × shape × layout):
  * every parameter's PartitionSpec divides its dimensions exactly.
"""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import lang
from repro.core.corpus import generate_app
from repro.core.hints import analyze_application, method_paths
from repro.core.type_graph import EXCLUDE_BRANCH_DEPENDENT, INCLUDE_BRANCH_DEPENDENT


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_classes=st.integers(2, 12),
    mpc=st.integers(1, 4),
)
def test_analysis_terminates_and_hints_are_schema_valid(seed, n_classes, mpc):
    app = generate_app(seed, n_classes=n_classes, methods_per_class=mpc)
    report = analyze_application(app)
    assoc = app.type_graph()
    # walkable: every hint follows associations declared in G_T
    by_owner = {}
    for (owner, fld), (target, card) in assoc.items():
        by_owner.setdefault(owner, {})[fld] = (target, card)

    def owner_chain_ok(start_cls, steps):
        cur = start_cls
        for fld, card in steps:
            fields = {}
            t = cur
            while t is not None:  # include supertype fields
                fields.update(by_owner.get(t, {}))
                t = app.classes[t].supertype if t in app.classes else None
            assert fld in fields, f"hint step {fld} not a field of {cur}"
            target, decl_card = fields[fld]
            assert card == decl_card
            cur = target

    for key, hints in report.full_hints.items():
        owner = key.split(".")[0]
        for h in hints:
            owner_chain_ok(owner, h.steps)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_exclude_policy_paths_subset_of_include(seed):
    app = generate_app(seed, n_classes=6, methods_per_class=3)
    from repro.core.type_graph import CAPreAnalysis

    analysis = CAPreAnalysis(app)
    graphs = analysis.analyze_all()
    for g in graphs.values():
        excl = method_paths(g, EXCLUDE_BRANCH_DEPENDENT)
        incl = method_paths(g, INCLUDE_BRANCH_DEPENDENT)
        assert excl <= incl


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_dedup_hints_subset_of_full(seed):
    app = generate_app(seed, n_classes=8, methods_per_class=3)
    report = analyze_application(app)
    for key in report.hints:
        assert set(report.hints[key]) <= set(report.full_hints[key])


# ---------------------------------------------------------------------------
# Sharding-rule validity across the whole assignment matrix
# ---------------------------------------------------------------------------


class _FakeMesh:
    """Shape-only stand-in (no devices needed for divisibility checks)."""

    def __init__(self, shape: dict):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_shape", [
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
])
@pytest.mark.parametrize("parallelism", ["tp", "fsdp"])
def test_param_shardings_divide_exactly(arch, mesh_shape, parallelism):
    from repro.launch.shardings import logical_rules
    from repro.models.model import Model

    cfg = get_config(arch).replace(parallelism=parallelism)
    mesh = _FakeMesh(mesh_shape)
    model = Model(cfg)
    for shape_cfg in SHAPES.values():
        if shape_cfg.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
            continue
        rules = logical_rules(cfg, shape_cfg, mesh)
        pspecs = model.param_pspecs(rules)
        abstract = model.abstract_params()
        flat_s = jax.tree.leaves(
            pspecs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or x.__class__.__name__ == "PartitionSpec"
        )
        flat_a = jax.tree.leaves(abstract)
        assert len(flat_s) == len(flat_a)
        for spec, aval in zip(flat_s, flat_a):
            for dim, entry in zip(aval.shape, tuple(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                n = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % n == 0, (
                    f"{arch}/{parallelism}/{shape_cfg.name}: dim {dim} "
                    f"not divisible by {axes} ({n}) in spec {spec}"
                )
