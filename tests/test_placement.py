"""Placement-as-a-policy: pluggable placement, replica-aware routing,
failure scenarios (straggler / crash / failover), demand stealing, and the
placement-equivalence + baseline byte-identity acceptance checks."""

import csv
import threading

import pytest

from repro.pos.client import POSClient
from repro.pos.latency import ZERO, LatencyModel, make_scenario
from repro.pos.placement import (
    ConsistentHashPlacement,
    LocalityAwarePlacement,
    RoundRobinPlacement,
    available_placements,
    make_placement,
    spread,
)
from repro.pos.store import (
    ExecutionContext,
    NoReplicaAvailable,
    ObjectStore,
    ServiceCrashed,
)
from repro.predict.evaluate import _catalog, evaluate_workload, record_workload
from repro.runtime.fault import StoreFaultDetector


# ---------------------------------------------------------------------------
# placement policies (unit)
# ---------------------------------------------------------------------------


def test_spread_walks_distinct_services_with_wraparound():
    assert spread(3, 4, 2) == (3, 0)
    assert spread(1, 4, 1) == (1,)
    assert spread(0, 4, 3) == (0, 1, 2)
    # replication capped at the service count
    assert spread(2, 3, 9) == (2, 0, 1)


def test_round_robin_matches_legacy_counter():
    p = RoundRobinPlacement(4, 1)
    assert [p.place(oid, "C") for oid in range(1, 6)] == [
        (0,), (1,), (2,), (3,), (0,)
    ]


def test_consistent_hash_is_deterministic_and_distinct():
    a = ConsistentHashPlacement(4, 2)
    b = ConsistentHashPlacement(4, 2)
    for oid in range(1, 50):
        reps = a.place(oid, "C")
        assert reps == b.place(oid, "C")  # pure function of the oid
        assert len(reps) == 2 and len(set(reps)) == 2


def test_locality_colocates_groups_and_rotates_ungrouped():
    p = LocalityAwarePlacement(4, 1)
    g1 = [p.place(oid, "C", group="g1") for oid in (1, 2, 3)]
    assert len({reps[0] for reps in g1}) == 1  # whole group on one service
    g2 = p.place(4, "C", group="g2")
    assert g2[0] != g1[0][0]  # next group lands on the next service
    # ungrouped objects keep consuming the same rotation
    singles = {p.place(oid, "C")[0] for oid in range(5, 9)}
    assert len(singles) == 4


def test_make_placement_rejects_unknown_policy():
    with pytest.raises(KeyError, match="unknown placement"):
        make_placement("nope", 4, 1)
    assert set(available_placements()) == {
        "round-robin", "consistent-hash", "locality"
    }


# ---------------------------------------------------------------------------
# store mechanics: replication, pinning, rebuild
# ---------------------------------------------------------------------------


def test_replication_registers_one_shared_instance():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C", {"x": 1})
    reps = store.replicas_of(oid)
    assert len(reps) == 2
    objs = [store.services[r].disk[oid] for r in reps]
    assert objs[0] is objs[1]  # field state trivially consistent


def test_pinned_put_does_not_advance_the_policy():
    pinned = ObjectStore(n_services=4, latency=ZERO)
    control = ObjectStore(n_services=4, latency=ZERO)
    a1 = pinned.put("C")
    pinned.put("C", ds=3)  # pinned: no counter consumption
    a2 = pinned.put("C")
    b1 = control.put("C")
    b2 = control.put("C")
    assert pinned.replicas_of(a1) == control.replicas_of(b1)
    assert pinned.replicas_of(a2) == control.replicas_of(b2)


def test_rebuild_placement_preserves_objects_and_honours_groups():
    store = ObjectStore(n_services=4, latency=ZERO)
    oids = [store.put("C", {"v": i}, group=f"g{i // 3}") for i in range(9)]
    before = {oid: store.peek(oid).fields["v"] for oid in oids}
    store.rebuild_placement("locality", replication=2)
    assert store.placement_name == "locality"
    for oid in oids:
        assert store.peek(oid).fields["v"] == before[oid]
        assert len(store.replicas_of(oid)) == 2
    # each group of three shares one primary after the rebuild
    for g in range(3):
        primaries = {store.replicas_of(oids[g * 3 + i])[0] for i in range(3)}
        assert len(primaries) == 1


# ---------------------------------------------------------------------------
# failure handling: crash, failover, detection
# ---------------------------------------------------------------------------


def test_demand_fails_over_to_surviving_replica():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C", {"x": 7})
    primary = store.replicas_of(oid)[0]
    store.crash_service(primary)
    ctx = ExecutionContext(store)
    obj = store.app_access(ctx, oid)
    assert obj.fields["x"] == 7
    assert primary in store._down
    assert store.metrics.services_crashed == 1


def test_unreplicated_crash_leaves_no_replica():
    store = ObjectStore(n_services=4, latency=ZERO, replication=1)
    oid = store.put("C")
    store.crash_service(store.replicas_of(oid)[0])
    with pytest.raises(NoReplicaAvailable):
        store.app_access(ExecutionContext(store), oid)


def test_silent_crash_detected_by_error_fast_path():
    """A crash nobody announced: routing still targets the service, the
    load raises ServiceCrashed, and the demand path retries a replica."""
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C", {"x": 1})
    primary = store.replicas_of(oid)[0]
    store.services[primary].crash()  # service-level: store not told
    assert primary not in store._down
    obj = store.app_access(ExecutionContext(store), oid)
    assert obj.fields["x"] == 1
    assert primary in store._down  # the error path announced it
    assert store.metrics.failovers >= 1


def test_heartbeat_monitor_flags_silent_service():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    t = [0.0]
    det = store.attach_fault_detection(heartbeat_timeout=1.0,
                                      clock=lambda: t[0], check_every=1)
    assert isinstance(det, StoreFaultDetector)
    t[0] = 2.0
    for ds_id in (1, 2, 3):
        det.beat(ds_id)
    det.tick(force=True)
    assert 0 in store._down
    assert {1, 2, 3}.isdisjoint(store._down)


def test_straggler_detector_flags_slow_disk():
    store = ObjectStore(n_services=4, latency=ZERO)
    det = store.attach_fault_detection(straggler_threshold=2.0,
                                      straggler_min_samples=4,
                                      straggler_patience=1, check_every=1)
    for _ in range(3):
        det.beat(0, 1.0)  # persistently ~100x the fleet median
        for ds_id in (1, 2, 3):
            det.beat(ds_id, 0.01)
    det.tick(force=True)
    assert 0 in store._slow
    assert store.metrics.stragglers_flagged >= 1


def test_prefetch_batch_redispatches_from_crashed_service():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oids = [store.put("C", {"v": i}) for i in range(8)]
    victim = store.replicas_of(oids[0])[0]
    store.services[victim].crash()  # silent: routing still targets it
    store.prefetch_batch(oids)
    assert store.metrics.failovers >= 1
    # every oid is resident on some surviving replica
    for oid in oids:
        assert any(oid in store.services[r].cache
                   for r in store.replicas_of(oid) if r != victim)


# ---------------------------------------------------------------------------
# demand stealing (satellite 1)
# ---------------------------------------------------------------------------


def test_demand_steals_claimed_but_unstarted_prefetch():
    store = ObjectStore(n_services=4, latency=ZERO)
    oid = store.put("C", {"x": 5})
    ds = store.service_of(oid)
    # a lane claimed the oid but has not started loading: steal window open
    ev = threading.Event()
    ev.lane_pending = True
    with ds._cache_lock:
        ds._inflight[oid] = ev
    obj = store.app_access(ExecutionContext(store), oid)
    assert obj.fields["x"] == 5
    assert ds.demand_steals == 1
    assert getattr(ev, "stolen", False)
    assert ev.is_set()  # coalesced waiters wake on the same event
    assert oid in ds.cache


def test_lane_skips_stolen_oids_without_loading():
    latency = LatencyModel(disk_load=0.0, remote_hop=0.0, write_back=0.0,
                           think=0.0, parallel_per_ds=1)
    store = ObjectStore(n_services=4, latency=latency)
    oid = store.put("C")
    ds = store.service_of(oid)
    ds._slots.acquire()  # hold the only disk arm: the lane parks pre-slot
    lane = threading.Thread(target=ds.load_batch, args=([oid],))
    lane.start()
    deadline = threading.Event()
    for _ in range(2000):
        with ds._cache_lock:
            ev = ds._inflight.get(oid)
            if ev is not None and getattr(ev, "lane_pending", False):
                break
        deadline.wait(0.001)
    else:
        pytest.fail("lane never claimed the oid")
    with ds._cache_lock:  # a demand stealer took it over
        ev.lane_pending = False
        ev.stolen = True
    ds._slots.release()
    lane.join(timeout=5.0)
    assert not lane.is_alive()
    assert ds.prefetch_loads == 0  # the lane dropped the stolen oid
    with ds._cache_lock:  # the event now belongs to the stealer
        assert ds._inflight.get(oid) is ev
        ds._inflight.pop(oid)
    ev.set()


# ---------------------------------------------------------------------------
# live crash under replication: all five apps complete correctly
# ---------------------------------------------------------------------------


APPS = ("bank", "wordcount", "kmeans", "oo7", "pga")


def _run_app(app: str, crash_after: int = 0):
    wl = _catalog()[app]
    client = POSClient(n_services=4, latency=ZERO, replication=2)
    client.register(wl.build_app())
    root = wl.populate(client.store)
    store = client.store
    with client.session(wl.name, mode="capre", parallel_workers=4) as s:
        if crash_after:
            seen = [0]

            def on_access(_oid, _store=store, _seen=seen):
                _seen[0] += 1
                if _seen[0] == crash_after:
                    _store.crash_service(0)

            store.access_listener = on_access
        result = wl.run_once(s, root)
        s.drain(30.0)
    return result, store


@pytest.mark.parametrize("app", APPS)
def test_apps_complete_correctly_through_service_crash(app):
    clean, _ = _run_app(app)
    crashed, store = _run_app(app, crash_after=20)
    assert crashed == clean  # identical traversal result despite the crash
    assert store.metrics.services_crashed == 1
    assert not store.services[0].alive


# ---------------------------------------------------------------------------
# replay acceptance: equivalence, byte-identity, failure regimes
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bank_recorded():
    return record_workload(_catalog()["bank"], runs=2)


def test_placement_equivalence_no_fault(bank_recorded):
    """With no failures the placement policy moves objects, not
    predictions: the prefetched sets — hence precision/recall/coverage —
    are identical for every predictor under every policy."""
    wl = _catalog()["bank"]
    per_policy = {}
    for placement in available_placements():
        rows = evaluate_workload(
            wl, modes=("capre", "rop"), recorded=bank_recorded,
            placement=placement, dispatch_modes=("batch",),
        )
        per_policy[placement] = {
            r.predictor: (r.precision, r.recall, r.coverage) for r in rows
        }
    baseline = per_policy["round-robin"]
    for placement, by_pred in per_policy.items():
        assert by_pred == baseline, f"{placement} changed the prefetched sets"


def test_round_robin_replication_one_reproduces_baseline_csv(bank_recorded):
    """The refactor's null case is byte-identical: default placement at
    replication 1 must reproduce the committed baseline.csv
    timely_coverage cells exactly (same floats, not within-tolerance)."""
    want = {}
    with open("artifacts/predict/baseline.csv", newline="") as fh:
        for row in csv.DictReader(fh):
            key = (row["app"], row["workload"], row["predictor"],
                   row["cache_capacity"], row["policy"], row["dispatch"])
            want[key] = row["timely_coverage"]
    wl = _catalog()["bank"]
    rows = evaluate_workload(wl, modes=("capre", "rop"),
                             recorded=bank_recorded,
                             cache_capacities=(0, 64), policies=("lru",),
                             dispatch_modes=("per-oid",))
    assert rows
    for r in rows:
        key = (r.app, r.workload, r.predictor, str(r.cache_capacity),
               r.policy, r.dispatch)
        assert key in want, f"baseline.csv lost row {key}"
        assert str(r.timely_coverage) == want[key], key


def test_crash_scenario_fails_over_and_degrades_gracefully(bank_recorded):
    wl = _catalog()["bank"]
    rows = evaluate_workload(
        wl, modes=("capre",), recorded=bank_recorded,
        placement="locality", replication=2,
        cache_capacities=(64,), policies=("lru",),
        scenarios=("no-fault", "straggler", "crash"),
    )
    by_scenario = {r.scenario: r for r in rows}
    assert set(by_scenario) == {"no-fault", "straggler", "crash"}
    clean, straggler, crash = (by_scenario[s] for s in
                               ("no-fault", "straggler", "crash"))
    assert crash.failovers > 0  # in-flight prefetches re-dispatched
    # every access was still served (completeness under failure): the
    # accessed universe (TP + FN) is the same in every regime
    accessed = clean.true_positives + clean.false_negatives
    assert crash.true_positives + crash.false_negatives == accessed
    assert straggler.true_positives + straggler.false_negatives == accessed
    # faults cost timeliness, never correctness
    assert crash.timely_coverage < clean.timely_coverage
    assert straggler.stall_seconds > clean.stall_seconds
    assert clean.scenario == "no-fault" and crash.replication == 2
    assert crash.placement == "locality"


def test_make_scenario_anchors_crash_inside_the_run():
    sc = make_scenario("crash", end_t=1.0)
    assert sc.is_fault and sc.crash_service == 0
    assert 0.0 < sc.crash_at < 1.0
    clean = make_scenario("no-fault", end_t=1.0)
    assert not clean.is_fault and clean.crash_service is None
    slow = make_scenario("straggler", end_t=1.0, straggler_scale=8.0)
    assert slow.straggler_scales().get(0) == 8.0
